//! Paged per-slot KV cache pool for continuous batching.
//!
//! The pre-paging pool gave every KV slot a full-length host buffer
//! `[L, 2, H, T, hd]` — a short prompt paid for the whole horizon, and
//! identical system-prompt prefixes were stored once per request. This
//! rewrite moves ownership to fixed-size **pages** (`page_len` tokens ×
//! `[L, 2, H, hd]`, allocator: [`PagePool`]): a slot holds a page
//! *table* covering exactly its written extent, pages are
//! reference-counted so several slots can map the same physical prefix
//! pages (`serving::prefix_cache` hands them out), and any write into a
//! shared page copies it first (copy-on-write, [`PagePool::try_page_mut`]).
//!
//! Cost model: the engine still round-trips KV through the host once
//! per decode step (the price of changing the bucket under AOT
//! fixed-shape artifacts), but the *host-resident* footprint is now
//! `Σ ceil(extent / page_len)` pages instead of `slots × T` planes, the
//! per-step scatter shrinks from the whole horizon to the one token
//! position the step wrote, and shared prefixes are stored once.
//! Gather still materializes a bucket-shaped `[L, 2, B, H, T, hd]`
//! buffer (zero beyond each slot's extent — exactly the bytes the old
//! contiguous pool produced, so the artifact path is bit-identical);
//! a future device-side page table slots in behind the same interface.
//!
//! Layout contract (matches `python/compile/aot.py`):
//! * batch KV: `[L, 2, B, H, T, hd]`, row-major;
//! * per-layer KV (orchestrated mode): `[2, B, H, T, hd]`;
//! * page: `[L, 2, H, page_len, hd]` — the batch layout with the batch
//!   axis removed and `T` cut into `page_len` runs.
//!
//! Stale-data guarantee: pages are zeroed at allocation
//! ([`PagePool::try_alloc`]) and a slot's extent only covers positions
//! it wrote or mapped, so a recycled page can never leak another
//! request's KV — property-tested in `tests/page_pool.rs` (the old
//! "prefill overwrites the whole slot" discipline no longer applies at
//! page granularity).

use crate::runtime::pages::PagePool;

/// One slot's view of the paged pool.
#[derive(Default)]
struct SlotPages {
    /// Page ids covering tokens `[0, table.len() * page_len)`.
    table: Vec<usize>,
    /// Valid token positions `[0, extent)`.
    extent: usize,
}

/// A preempted slot's KV, detached from the pool's slot array: the
/// page table still holds its references (nothing is copied or
/// freed), so the pages cannot be recycled while parked. Restore with
/// [`KvSlotPool::unpark`] — into *any* empty slot, not necessarily
/// the original — or free with [`KvSlotPool::drop_parked`]. Fields
/// are private: a parked table can only go back through the pool that
/// issued it.
#[derive(Debug)]
pub struct ParkedSlot {
    table: Vec<usize>,
    extent: usize,
}

impl ParkedSlot {
    /// Valid token positions the parked table covers.
    pub fn tokens(&self) -> usize {
        self.extent
    }

    /// Number of pages kept resident while parked.
    pub fn page_count(&self) -> usize {
        self.table.len()
    }
}

/// Typed KV pool failure: the recoverable alternative to the
/// reserve-first panic path, for backends that want pool pressure to
/// surface as a per-request error instead of a process abort.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvPoolError {
    /// No free page and nothing evictable — the write cannot proceed.
    Exhausted,
}

impl std::fmt::Display for KvPoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvPoolError::Exhausted => write!(f, "kv page pool exhausted"),
        }
    }
}

impl std::error::Error for KvPoolError {}

/// Host-side pool of per-slot paged KV.
pub struct KvSlotPool {
    layers: usize,
    heads: usize,
    /// KV horizon `T` of the batch buffers. Host-only users (the stub
    /// forward) may pass a huge value; batch gathers are then unusable
    /// but token reads/writes (all they need) are fine.
    kv_len: usize,
    head_dim: usize,
    pages: PagePool,
    slots: Vec<SlotPages>,
    /// Shared-prefix mappings performed (gauge).
    pub shared_maps: u64,
}

impl KvSlotPool {
    /// `max_pages = None` grows on demand; the artifact engine passes
    /// `pool * ceil(kv_len / page_len)` so the worst case (every slot
    /// fully private at full horizon) always fits and prefix sharing
    /// only ever *frees* headroom.
    pub fn new(
        pool: usize,
        layers: usize,
        heads: usize,
        kv_len: usize,
        head_dim: usize,
        page_len: usize,
        max_pages: Option<usize>,
    ) -> KvSlotPool {
        assert!(page_len >= 1, "page_len 0 is not a page");
        let page_elems = layers * 2 * heads * page_len * head_dim;
        KvSlotPool {
            layers,
            heads,
            kv_len,
            head_dim,
            pages: PagePool::new(page_len, page_elems, max_pages),
            slots: (0..pool).map(|_| SlotPages::default()).collect(),
            shared_maps: 0,
        }
    }

    pub fn pool_size(&self) -> usize {
        self.slots.len()
    }

    pub fn kv_len(&self) -> usize {
        self.kv_len
    }

    pub fn page_len(&self) -> usize {
        self.pages.page_len()
    }

    /// The allocator (gauges: high-water pages, COW copies, …).
    pub fn pages(&self) -> &PagePool {
        &self.pages
    }

    /// Mutable allocator access for the prefix cache (retain on
    /// insert, release on eviction).
    pub fn pages_mut(&mut self) -> &mut PagePool {
        &mut self.pages
    }

    /// Elements in one token's column across all `[L, 2, H, hd]` planes.
    pub fn token_elems(&self) -> usize {
        self.layers * 2 * self.heads * self.head_dim
    }

    /// Elements in a full batch buffer at `bucket` rows.
    pub fn batch_elems(&self, bucket: usize) -> usize {
        self.layers * 2 * bucket * self.heads * self.kv_len * self.head_dim
    }

    /// Elements in one layer's batch buffer at `bucket` rows.
    pub fn layer_elems(&self, bucket: usize) -> usize {
        2 * bucket * self.heads * self.kv_len * self.head_dim
    }

    /// Valid token positions of `slot` (`0` = empty).
    pub fn extent(&self, slot: usize) -> usize {
        self.slots[slot].extent
    }

    /// The slot's page table (ids, in token order).
    pub fn slot_pages(&self, slot: usize) -> &[usize] {
        &self.slots[slot].table
    }

    /// Pages the slot still needs to cover `tokens` positions.
    pub fn pages_to_cover(&self, slot: usize, tokens: usize) -> usize {
        let pl = self.pages.page_len();
        let need = (tokens + pl - 1) / pl;
        need.saturating_sub(self.slots[slot].table.len())
    }

    /// Pages allocatable without eviction (`None` = unbounded).
    pub fn pages_available(&self) -> Option<usize> {
        self.pages.available()
    }

    /// Map shared prefix pages into an **empty** slot (one reference
    /// each). `tokens` must equal the pages' full coverage — partial
    /// final pages are never shared, so a slot's gather stays
    /// bit-identical to the unshared path.
    pub fn map_shared(&mut self, slot: usize, pages: &[usize], tokens: usize) {
        let st = &self.slots[slot];
        assert!(st.table.is_empty() && st.extent == 0, "map_shared into an occupied slot {slot}");
        assert_eq!(tokens, pages.len() * self.pages.page_len(), "shared mapping must be whole pages");
        for &p in pages {
            self.pages.retain(p);
        }
        let st = &mut self.slots[slot];
        st.table.extend_from_slice(pages);
        st.extent = tokens;
        self.shared_maps += 1;
    }

    /// Grow the slot's table to cover `tokens` positions with fresh
    /// zeroed pages. Panics on pool exhaustion — callers reserve
    /// headroom first (evicting prefix-cache holds under pressure).
    fn ensure_pages(&mut self, slot: usize, tokens: usize) {
        let pl = self.pages.page_len();
        let need = (tokens + pl - 1) / pl;
        while self.slots[slot].table.len() < need {
            let p = self
                .pages
                .try_alloc()
                // lint: allow(panic-discipline) — documented reserve-first contract: callers check/evict headroom before writing, and worst-case page demand is sized at admission, so exhaustion here is a scheduler accounting bug
                .expect("kv page pool exhausted — reserve/evict before writing");
            self.slots[slot].table.push(p);
        }
    }

    /// Write one token column (`token_elems` values, plane order
    /// `[L, 2, H, hd]`) at position `pos`, allocating/COW-ing pages as
    /// needed.
    pub fn write_token(&mut self, slot: usize, pos: usize, col: &[f32]) {
        self.try_write_token(slot, pos, col)
            // lint: allow(panic-discipline) — documented reserve-first contract: the fallible try_write_token is the serving-path API; this infallible wrapper is for callers that sized the pool at admission
            .expect("kv page pool exhausted — reserve/evict before writing");
    }

    /// Fallible [`KvSlotPool::write_token`]: pool exhaustion (fresh
    /// page or COW copy) comes back as [`KvPoolError::Exhausted`]
    /// instead of a panic, with the slot's prior pages untouched —
    /// the fault-containment entry point for host-side backends.
    pub fn try_write_token(
        &mut self,
        slot: usize,
        pos: usize,
        col: &[f32],
    ) -> Result<(), KvPoolError> {
        assert_eq!(col.len(), self.token_elems(), "kv token column size");
        let pl = self.pages.page_len();
        let need = pos / pl + 1;
        while self.slots[slot].table.len() < need {
            let p = self.pages.try_alloc().ok_or(KvPoolError::Exhausted)?;
            self.slots[slot].table.push(p);
        }
        let hd = self.head_dim;
        let tp = pos % pl;
        let st = &mut self.slots[slot];
        let page = self
            .pages
            .try_page_mut(&mut st.table[pos / pl])
            .ok_or(KvPoolError::Exhausted)?;
        for ph in 0..self.layers * 2 * self.heads {
            let dst = (ph * pl + tp) * hd;
            page[dst..dst + hd].copy_from_slice(&col[ph * hd..(ph + 1) * hd]);
        }
        st.extent = st.extent.max(pos + 1);
        Ok(())
    }

    /// Read one token column at `pos` (must be below the extent).
    pub fn read_token(&self, slot: usize, pos: usize, col: &mut [f32]) {
        assert_eq!(col.len(), self.token_elems(), "kv token column size");
        let st = &self.slots[slot];
        assert!(pos < st.extent, "kv read at {pos} beyond extent {}", st.extent);
        let (pl, hd) = (self.pages.page_len(), self.head_dim);
        let page = self.pages.page(st.table[pos / pl]);
        let tp = pos % pl;
        for ph in 0..self.layers * 2 * self.heads {
            let src = (ph * pl + tp) * hd;
            col[ph * hd..(ph + 1) * hd].copy_from_slice(&page[src..src + hd]);
        }
    }

    /// Copy token range `[from, to)` of row `row` in a downloaded
    /// `[L, 2, B, H, T, hd]` batch buffer into the slot's pages
    /// (prefill ingest stores `[cached, s)`; a decode step stores the
    /// one position it wrote).
    pub fn store_from_batch(
        &mut self,
        slot: usize,
        batch: &[f32],
        bucket: usize,
        row: usize,
        from: usize,
        to: usize,
    ) {
        assert_eq!(batch.len(), self.batch_elems(bucket), "kv batch size");
        assert!(row < bucket && from <= to && to <= self.kv_len, "kv store range");
        self.ensure_pages(slot, to);
        let (pl, hd, t) = (self.pages.page_len(), self.head_dim, self.kv_len);
        let heads = self.heads;
        for pi in from / pl..(to + pl - 1) / pl {
            let t0 = (pi * pl).max(from);
            let t1 = ((pi + 1) * pl).min(to);
            let st = &mut self.slots[slot];
            let page = self
                .pages
                .try_page_mut(&mut st.table[pi])
                // lint: allow(panic-discipline) — COW headroom is part of the admission-time reservation (one page per shared page worst case); exhaustion here means the reservation math broke, not a request fault
                .expect("kv page pool exhausted during COW");
            for lc in 0..self.layers * 2 {
                for h in 0..heads {
                    let src = (((lc * bucket + row) * heads + h) * t + t0) * hd;
                    let dst = ((lc * heads + h) * pl + (t0 - pi * pl)) * hd;
                    page[dst..dst + (t1 - t0) * hd]
                        .copy_from_slice(&batch[src..src + (t1 - t0) * hd]);
                }
            }
        }
        let st = &mut self.slots[slot];
        st.extent = st.extent.max(to);
    }

    /// Layer-view variant of [`KvSlotPool::store_from_batch`]:
    /// `batch` is one layer's `[2, B, H, T, hd]` buffer.
    #[allow(clippy::too_many_arguments)]
    pub fn store_layer_from_batch(
        &mut self,
        layer: usize,
        slot: usize,
        batch: &[f32],
        bucket: usize,
        row: usize,
        from: usize,
        to: usize,
    ) {
        assert_eq!(batch.len(), self.layer_elems(bucket), "kv layer size");
        assert!(layer < self.layers && row < bucket && from <= to && to <= self.kv_len);
        self.ensure_pages(slot, to);
        let (pl, hd, t) = (self.pages.page_len(), self.head_dim, self.kv_len);
        let heads = self.heads;
        for pi in from / pl..(to + pl - 1) / pl {
            let t0 = (pi * pl).max(from);
            let t1 = ((pi + 1) * pl).min(to);
            let st = &mut self.slots[slot];
            let page = self
                .pages
                .try_page_mut(&mut st.table[pi])
                // lint: allow(panic-discipline) — COW headroom is part of the admission-time reservation (one page per shared page worst case); exhaustion here means the reservation math broke, not a request fault
                .expect("kv page pool exhausted during COW");
            for c in 0..2 {
                for h in 0..heads {
                    let src = (((c * bucket + row) * heads + h) * t + t0) * hd;
                    let dst = (((layer * 2 + c) * heads + h) * pl + (t0 - pi * pl)) * hd;
                    page[dst..dst + (t1 - t0) * hd]
                        .copy_from_slice(&batch[src..src + (t1 - t0) * hd]);
                }
            }
        }
        let st = &mut self.slots[slot];
        st.extent = st.extent.max(to);
    }

    /// Build a `[L, 2, bucket, H, T, hd]` batch buffer from `rows`
    /// (slot ids, one per live row); positions beyond each slot's
    /// mapped pages — and rows beyond `rows.len()` — are zero. `out`
    /// is resized and fully overwritten.
    pub fn gather_full(&self, rows: &[usize], bucket: usize, out: &mut Vec<f32>) {
        assert!(rows.len() <= bucket);
        out.clear();
        out.resize(self.batch_elems(bucket), 0.0);
        let (pl, hd, t) = (self.pages.page_len(), self.head_dim, self.kv_len);
        let heads = self.heads;
        for (b, &slot) in rows.iter().enumerate() {
            for (pi, &p) in self.slots[slot].table.iter().enumerate() {
                let t0 = pi * pl;
                let n = pl.min(t - t0);
                let page = self.pages.page(p);
                for lc in 0..self.layers * 2 {
                    for h in 0..heads {
                        let src = (lc * heads + h) * pl * hd;
                        let dst = (((lc * bucket + b) * heads + h) * t + t0) * hd;
                        out[dst..dst + n * hd].copy_from_slice(&page[src..src + n * hd]);
                    }
                }
            }
        }
    }

    /// Build one layer's `[2, bucket, H, T, hd]` batch buffer
    /// (orchestrated mode uploads KV per layer).
    pub fn gather_layer(&self, layer: usize, rows: &[usize], bucket: usize, out: &mut Vec<f32>) {
        assert!(layer < self.layers && rows.len() <= bucket);
        out.clear();
        out.resize(self.layer_elems(bucket), 0.0);
        let (pl, hd, t) = (self.pages.page_len(), self.head_dim, self.kv_len);
        let heads = self.heads;
        for (b, &slot) in rows.iter().enumerate() {
            for (pi, &p) in self.slots[slot].table.iter().enumerate() {
                let t0 = pi * pl;
                let n = pl.min(t - t0);
                let page = self.pages.page(p);
                for c in 0..2 {
                    for h in 0..heads {
                        let src = ((layer * 2 + c) * heads + h) * pl * hd;
                        let dst = (((c * bucket + b) * heads + h) * t + t0) * hd;
                        out[dst..dst + n * hd].copy_from_slice(&page[src..src + n * hd]);
                    }
                }
            }
        }
    }

    /// The slot retired: drop every page reference (shared pages live
    /// on under the prefix cache's hold; private ones return to the
    /// free list zeroed-on-reuse).
    pub fn release(&mut self, slot: usize) {
        let table = std::mem::take(&mut self.slots[slot].table);
        for p in table {
            self.pages.release(p);
        }
        self.slots[slot].extent = 0;
    }

    /// Preemption: detach `slot`'s page table without touching any
    /// refcount. The slot reads as empty afterwards (assignable to a
    /// new request); the parked pages stay resident — and cannot be
    /// recycled — until [`KvSlotPool::unpark`] or
    /// [`KvSlotPool::drop_parked`].
    pub fn park(&mut self, slot: usize) -> ParkedSlot {
        let st = &mut self.slots[slot];
        ParkedSlot { table: std::mem::take(&mut st.table), extent: std::mem::take(&mut st.extent) }
    }

    /// Restore a parked table into an **empty** slot (any slot, not
    /// necessarily the one it was parked from). Refcounts are again
    /// untouched: the references simply move back from the parked
    /// handle to the slot.
    pub fn unpark(&mut self, slot: usize, parked: ParkedSlot) {
        let st = &mut self.slots[slot];
        assert!(st.table.is_empty() && st.extent == 0, "unpark into an occupied slot {slot}");
        st.table = parked.table;
        st.extent = parked.extent;
    }

    /// Free a parked table without restoring it (the victim was
    /// aborted, or resumes through drop+recompute instead).
    pub fn drop_parked(&mut self, parked: ParkedSlot) {
        for p in parked.table {
            self.pages.release(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Batch buffer whose element value encodes (lc, row, plane index)
    /// so any layout mistake shows up as a mismatch somewhere.
    fn filled_batch(pool: &KvSlotPool, bucket: usize, tag: f32) -> Vec<f32> {
        let plane = pool.heads * pool.kv_len * pool.head_dim;
        let mut v = vec![0.0; pool.batch_elems(bucket)];
        for lc in 0..pool.layers * 2 {
            for b in 0..bucket {
                for p in 0..plane {
                    v[(lc * bucket + b) * plane + p] =
                        tag + lc as f32 * 1000.0 + b as f32 * 10.0 + p as f32 * 0.001;
                }
            }
        }
        v
    }

    #[test]
    fn store_gather_roundtrip_with_pages() {
        // page_len 2 over T=6: three pages per full slot
        let mut pool = KvSlotPool::new(4, 2, 2, 6, 2, 2, None);
        let batch = filled_batch(&pool, 3, 0.5);
        pool.store_from_batch(2, &batch, 3, 1, 0, 6);
        pool.store_from_batch(0, &batch, 3, 0, 0, 4); // partial extent
        let plane = 2 * 6 * 2;
        let mut out = Vec::new();
        pool.gather_full(&[2, 0], 4, &mut out);
        for lc in 0..4 {
            for p in 0..plane {
                let want_r0 = batch[(lc * 3 + 1) * plane + p];
                // slot 0 only covers tokens [0, 4): positions 4..6 zero
                let tok = p / 2 % 6;
                let want_r1 = if tok < 4 { batch[(lc * 3) * plane + p] } else { 0.0 };
                assert_eq!(out[(lc * 4) * plane + p], want_r0);
                assert_eq!(out[(lc * 4 + 1) * plane + p], want_r1);
                assert_eq!(out[(lc * 4 + 2) * plane + p], 0.0);
                assert_eq!(out[(lc * 4 + 3) * plane + p], 0.0);
            }
        }
        assert_eq!(pool.pages().pages_in_use(), 3 + 2);
    }

    #[test]
    fn token_store_matches_full_store() {
        // writing position `pos` via store_from_batch([pos, pos+1)) is
        // the decode scatter; it must agree with a full-range store
        let mut a = KvSlotPool::new(2, 2, 1, 5, 2, 2, None);
        let mut b = KvSlotPool::new(2, 2, 1, 5, 2, 2, None);
        let batch = filled_batch(&a, 1, 3.0);
        a.store_from_batch(0, &batch, 1, 0, 0, 5);
        for pos in 0..5 {
            b.store_from_batch(0, &batch, 1, 0, pos, pos + 1);
        }
        let (mut va, mut vb) = (Vec::new(), Vec::new());
        a.gather_full(&[0], 1, &mut va);
        b.gather_full(&[0], 1, &mut vb);
        assert_eq!(va, vb);
    }

    #[test]
    fn layer_view_matches_full_view() {
        let mut pool = KvSlotPool::new(2, 3, 2, 4, 2, 3, None);
        let batch = filled_batch(&pool, 2, 3.0);
        for row in 0..2 {
            pool.store_from_batch(row, &batch, 2, row, 0, 4);
        }
        let plane = 2 * 4 * 2;
        for l in 0..3 {
            let mut lv = Vec::new();
            pool.gather_layer(l, &[0, 1], 2, &mut lv);
            for c in 0..2 {
                for b in 0..2 {
                    let full = ((l * 2 + c) * 2 + b) * plane;
                    let lay = (c * 2 + b) * plane;
                    let mut fv = Vec::new();
                    pool.gather_full(&[0, 1], 2, &mut fv);
                    assert_eq!(&lv[lay..lay + plane], &fv[full..full + plane]);
                }
            }
        }
        // layer-wise token scatter feeds back into the full view
        let mut lv = Vec::new();
        pool.gather_layer(1, &[1], 1, &mut lv);
        for x in lv.iter_mut() {
            *x += 100.0;
        }
        pool.store_layer_from_batch(1, 1, &lv, 1, 0, 2, 3);
        let mut full = Vec::new();
        pool.gather_full(&[1], 1, &mut full);
        for c in 0..2 {
            for h in 0..2 {
                for t in 0..4 {
                    for d in 0..2 {
                        let p = (h * 4 + t) * 2 + d;
                        let got = full[(2 + c) * plane + p];
                        let base = batch[((2 + c) * 2 + 1) * plane + p];
                        let want = if t == 2 { base + 100.0 } else { base };
                        assert_eq!(got, want, "c={c} h={h} t={t} d={d}");
                    }
                }
            }
        }
    }

    #[test]
    fn write_read_token_roundtrip_and_extent() {
        let mut pool = KvSlotPool::new(2, 1, 1, usize::MAX / 4, 1, 4, None);
        assert_eq!(pool.token_elems(), 2);
        pool.write_token(0, 0, &[5.0, -5.0]);
        pool.write_token(0, 6, &[7.0, -7.0]); // skips ahead: gap stays zero
        assert_eq!(pool.extent(0), 7);
        let mut col = [9.0f32; 2];
        pool.read_token(0, 0, &mut col);
        assert_eq!(col, [5.0, -5.0]);
        pool.read_token(0, 3, &mut col);
        assert_eq!(col, [0.0, 0.0], "unwritten positions read zero");
        pool.read_token(0, 6, &mut col);
        assert_eq!(col, [7.0, -7.0]);
        assert_eq!(pool.pages().pages_in_use(), 2);
    }

    #[test]
    fn shared_mapping_cow_and_release() {
        let mut pool = KvSlotPool::new(3, 1, 1, 64, 1, 2, None);
        for t in 0..4 {
            pool.write_token(0, t, &[t as f32 + 1.0, 0.0]);
        }
        // share slot 0's two pages into slot 1 (as the prefix cache would)
        let pages: Vec<usize> = pool.slot_pages(0).to_vec();
        pool.map_shared(1, &pages, 4);
        assert_eq!(pool.extent(1), 4);
        let mut col = [0.0f32; 2];
        pool.read_token(1, 2, &mut col);
        assert_eq!(col[0], 3.0);
        assert_eq!(pool.pages().pages_in_use(), 2, "shared pages are stored once");
        // divergent write in slot 1 COWs; slot 0 keeps its bytes
        pool.write_token(1, 3, &[99.0, 0.0]);
        pool.read_token(0, 3, &mut col);
        assert_eq!(col[0], 4.0);
        pool.read_token(1, 3, &mut col);
        assert_eq!(col[0], 99.0);
        assert_eq!(pool.pages().cow_copies, 1);
        assert_eq!(pool.pages().pages_in_use(), 3);
        // releases drop references; the still-shared page survives
        pool.release(1);
        assert_eq!(pool.pages().pages_in_use(), 2);
        pool.release(0);
        assert_eq!(pool.pages().pages_in_use(), 0);
    }

    #[test]
    fn park_unpark_roundtrip_keeps_bytes_and_refcounts() {
        let mut pool = KvSlotPool::new(3, 1, 1, 64, 1, 2, None);
        for t in 0..5 {
            pool.write_token(0, t, &[t as f32 + 1.0, 0.0]);
        }
        let in_use = pool.pages().pages_in_use();
        let parked = pool.park(0);
        assert_eq!(parked.tokens(), 5);
        assert_eq!(parked.page_count(), 3);
        // the slot reads empty, but the pages stay resident
        assert_eq!(pool.extent(0), 0);
        assert!(pool.slot_pages(0).is_empty());
        assert_eq!(pool.pages().pages_in_use(), in_use);
        // another request can use the vacated slot meanwhile
        pool.write_token(0, 0, &[42.0, 0.0]);
        pool.release(0);
        // restore into a different slot: bytes identical
        pool.unpark(2, parked);
        assert_eq!(pool.extent(2), 5);
        let mut col = [0.0f32; 2];
        for t in 0..5 {
            pool.read_token(2, t, &mut col);
            assert_eq!(col[0], t as f32 + 1.0);
        }
        pool.release(2);
        assert_eq!(pool.pages().pages_in_use(), 0);
    }

    #[test]
    fn park_preserves_shared_page_references() {
        let mut pool = KvSlotPool::new(3, 1, 1, 64, 1, 2, None);
        for t in 0..2 {
            pool.write_token(0, t, &[t as f32, 0.0]);
        }
        let pages: Vec<usize> = pool.slot_pages(0).to_vec();
        pool.map_shared(1, &pages, 2);
        // park the sharer, then retire the original: the page must
        // survive on the parked table's reference alone
        let parked = pool.park(1);
        pool.release(0);
        assert_eq!(pool.pages().pages_in_use(), 1);
        let mut col = [0.0f32; 2];
        pool.unpark(1, parked);
        pool.read_token(1, 1, &mut col);
        assert_eq!(col[0], 1.0);
        pool.release(1);
        assert_eq!(pool.pages().pages_in_use(), 0);
    }

    #[test]
    fn drop_parked_frees_pages() {
        let mut pool = KvSlotPool::new(2, 1, 1, 64, 1, 2, None);
        pool.write_token(0, 3, &[1.0, 1.0]);
        let parked = pool.park(0);
        assert_eq!(pool.pages().pages_in_use(), 2);
        pool.drop_parked(parked);
        assert_eq!(pool.pages().pages_in_use(), 0);
    }

    #[test]
    fn try_write_token_reports_exhaustion_without_panicking() {
        // 2 pages total, page_len 2
        let mut pool = KvSlotPool::new(2, 1, 1, 64, 1, 2, Some(2));
        assert!(pool.try_write_token(0, 0, &[1.0, 1.0]).is_ok());
        assert!(pool.try_write_token(0, 3, &[1.0, 1.0]).is_ok());
        assert_eq!(pool.try_write_token(1, 0, &[1.0, 1.0]), Err(KvPoolError::Exhausted));
        // the failed writer's slot is untouched and the pool still works
        assert_eq!(pool.extent(1), 0);
        pool.release(0);
        assert!(pool.try_write_token(1, 0, &[1.0, 1.0]).is_ok());
    }

    #[test]
    fn high_water_tracks_pages_not_slots() {
        let mut pool = KvSlotPool::new(4, 1, 1, 8, 1, 2, None);
        pool.write_token(0, 0, &[1.0, 1.0]);
        pool.write_token(3, 5, &[1.0, 1.0]); // 3 pages for positions [0,6)
        pool.release(0);
        pool.write_token(0, 0, &[1.0, 1.0]); // recycles, no new high water
        assert_eq!(pool.pages().high_water_pages, 4);
        assert_eq!(pool.pages_to_cover(3, 8), 1);
        assert_eq!(pool.pages_to_cover(3, 6), 0);
    }
}
