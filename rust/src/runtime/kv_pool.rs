//! Per-slot KV cache pool for continuous batching.
//!
//! The wave engine keeps one device-resident KV buffer per wave,
//! shaped `[L, 2, bucket, H, T, hd]` — fine when batch membership is
//! frozen for the wave's lifetime. Continuous batching changes batch
//! membership (and the bucket) every step, so KV ownership moves to
//! the *slot*: each KV slot owns a host-resident `[L, 2, H, T, hd]`
//! buffer, and every step the engine gathers the live slots' rows into
//! a bucket-shaped batch buffer, runs the compiled step, and scatters
//! the updated rows back.
//!
//! Cost model: this round-trips KV through the host once per decode
//! step — the price of changing the bucket under AOT-compiled
//! fixed-shape artifacts. The wave path keeps its device-resident KV
//! (no regression there); a future device-side slot pool (a
//! `gather_kv`/`scatter_kv` artifact pair) slots in behind the same
//! gather/scatter interface. Scheduling correctness is independent of
//! where KV lives, which is what the scheduler test suites exercise.
//!
//! Layout contract (matches `python/compile/aot.py`):
//! * batch KV: `[L, 2, B, H, T, hd]`, row-major;
//! * per-layer KV (orchestrated mode): `[2, B, H, T, hd]`;
//! * slot KV: `[L, 2, H, T, hd]` — the batch layout with the batch
//!   axis removed.
//!
//! Slots allocate lazily on first write and keep their buffer across
//! release/reuse (prefill overwrites the whole slot, including the
//! zero padding beyond the prompt, so stale data can never leak into a
//! recycled slot).

/// Host-side pool of per-slot KV buffers.
pub struct KvSlotPool {
    layers: usize,
    kv_len: usize,
    /// Elements in one `[H, T, hd]` plane.
    plane: usize,
    /// Elements in one slot buffer: `layers * 2 * plane`.
    slot_elems: usize,
    slots: Vec<Option<Vec<f32>>>,
    /// Most slots ever allocated at once (memory gauge).
    pub high_water_slots: usize,
}

impl KvSlotPool {
    pub fn new(
        pool: usize,
        layers: usize,
        heads: usize,
        kv_len: usize,
        head_dim: usize,
    ) -> KvSlotPool {
        let plane = heads * kv_len * head_dim;
        KvSlotPool {
            layers,
            kv_len,
            plane,
            slot_elems: layers * 2 * plane,
            slots: (0..pool).map(|_| None).collect(),
            high_water_slots: 0,
        }
    }

    pub fn pool_size(&self) -> usize {
        self.slots.len()
    }

    pub fn kv_len(&self) -> usize {
        self.kv_len
    }

    /// Elements in a full batch buffer at `bucket` rows.
    pub fn batch_elems(&self, bucket: usize) -> usize {
        self.slot_elems * bucket
    }

    /// Elements in one layer's batch buffer at `bucket` rows.
    pub fn layer_elems(&self, bucket: usize) -> usize {
        2 * bucket * self.plane
    }

    fn ensure(&mut self, slot: usize) -> &mut Vec<f32> {
        if self.slots[slot].is_none() {
            self.slots[slot] = Some(vec![0.0; self.slot_elems]);
            let n = self.slots.iter().filter(|s| s.is_some()).count();
            self.high_water_slots = self.high_water_slots.max(n);
        }
        self.slots[slot].as_mut().unwrap()
    }

    /// Copy row `row` of a downloaded `[L, 2, B, H, T, hd]` batch
    /// buffer into `slot` (prefill ingest — full overwrite).
    pub fn store_from_batch(&mut self, slot: usize, batch: &[f32], bucket: usize, row: usize) {
        assert_eq!(batch.len(), self.batch_elems(bucket), "kv batch size");
        assert!(row < bucket);
        let plane = self.plane;
        let buf = self.ensure(slot);
        for lc in 0..self.layers * 2 {
            let src = (lc * bucket + row) * plane;
            let dst = lc * plane;
            buf[dst..dst + plane].copy_from_slice(&batch[src..src + plane]);
        }
    }

    /// Build a `[L, 2, bucket, H, T, hd]` batch buffer from `rows`
    /// (slot ids, one per live row); rows beyond `rows.len()` are
    /// zero. `out` is resized and fully overwritten.
    pub fn gather_full(&self, rows: &[usize], bucket: usize, out: &mut Vec<f32>) {
        assert!(rows.len() <= bucket);
        out.clear();
        out.resize(self.batch_elems(bucket), 0.0);
        let plane = self.plane;
        for lc in 0..self.layers * 2 {
            for (b, &slot) in rows.iter().enumerate() {
                let buf = self.slots[slot].as_ref().expect("gather from empty kv slot");
                let src = lc * plane;
                let dst = (lc * bucket + b) * plane;
                out[dst..dst + plane].copy_from_slice(&buf[src..src + plane]);
            }
        }
    }

    /// Scatter the live rows of an updated `[L, 2, bucket, H, T, hd]`
    /// batch buffer back into their slots.
    pub fn scatter_full(&mut self, rows: &[usize], bucket: usize, batch: &[f32]) {
        assert!(rows.len() <= bucket);
        assert_eq!(batch.len(), self.batch_elems(bucket), "kv batch size");
        let plane = self.plane;
        for (b, &slot) in rows.iter().enumerate() {
            let buf = self.ensure(slot);
            for lc in 0..self.layers * 2 {
                let src = (lc * bucket + b) * plane;
                let dst = lc * plane;
                buf[dst..dst + plane].copy_from_slice(&batch[src..src + plane]);
            }
        }
    }

    /// Build one layer's `[2, bucket, H, T, hd]` batch buffer
    /// (orchestrated mode uploads KV per layer).
    pub fn gather_layer(&self, layer: usize, rows: &[usize], bucket: usize, out: &mut Vec<f32>) {
        assert!(layer < self.layers && rows.len() <= bucket);
        out.clear();
        out.resize(self.layer_elems(bucket), 0.0);
        let plane = self.plane;
        for c in 0..2 {
            for (b, &slot) in rows.iter().enumerate() {
                let buf = self.slots[slot].as_ref().expect("gather from empty kv slot");
                let src = (layer * 2 + c) * plane;
                let dst = (c * bucket + b) * plane;
                out[dst..dst + plane].copy_from_slice(&buf[src..src + plane]);
            }
        }
    }

    /// Scatter one layer's updated `[2, bucket, H, T, hd]` buffer back.
    pub fn scatter_layer(&mut self, layer: usize, rows: &[usize], bucket: usize, batch: &[f32]) {
        assert!(layer < self.layers && rows.len() <= bucket);
        assert_eq!(batch.len(), self.layer_elems(bucket), "kv layer size");
        let plane = self.plane;
        for (b, &slot) in rows.iter().enumerate() {
            let buf = self.ensure(slot);
            for c in 0..2 {
                let src = (c * bucket + b) * plane;
                let dst = (layer * 2 + c) * plane;
                buf[dst..dst + plane].copy_from_slice(&batch[src..src + plane]);
            }
        }
    }

    /// The slot retired. The buffer is kept for reuse — the next
    /// prefill overwrites it end to end.
    pub fn release(&mut self, _slot: usize) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled_batch(pool: &KvSlotPool, bucket: usize, tag: f32) -> Vec<f32> {
        // element value encodes (lc, row, plane index) so any layout
        // mistake shows up as a mismatch somewhere
        let plane = pool.plane;
        let mut v = vec![0.0; pool.batch_elems(bucket)];
        for lc in 0..pool.layers * 2 {
            for b in 0..bucket {
                for p in 0..plane {
                    v[(lc * bucket + b) * plane + p] =
                        tag + lc as f32 * 1000.0 + b as f32 * 10.0 + p as f32 * 0.001;
                }
            }
        }
        v
    }

    #[test]
    fn store_gather_roundtrip() {
        let mut pool = KvSlotPool::new(4, 2, 2, 3, 2);
        let batch = filled_batch(&pool, 3, 0.5);
        pool.store_from_batch(2, &batch, 3, 1);
        pool.store_from_batch(0, &batch, 3, 0);
        // gather [slot2, slot0] at bucket 4: row 0 ← slot2 (batch row 1),
        // row 1 ← slot0 (batch row 0), rows 2..4 zero
        let mut out = Vec::new();
        pool.gather_full(&[2, 0], 4, &mut out);
        let plane = 2 * 3 * 2;
        for lc in 0..4 {
            for p in 0..plane {
                let want_r0 = batch[(lc * 3 + 1) * plane + p];
                let want_r1 = batch[(lc * 3) * plane + p];
                assert_eq!(out[(lc * 4) * plane + p], want_r0);
                assert_eq!(out[(lc * 4 + 1) * plane + p], want_r1);
                assert_eq!(out[(lc * 4 + 2) * plane + p], 0.0);
                assert_eq!(out[(lc * 4 + 3) * plane + p], 0.0);
            }
        }
    }

    #[test]
    fn scatter_then_gather_is_identity_on_live_rows() {
        let mut pool = KvSlotPool::new(3, 2, 2, 2, 2);
        let batch = filled_batch(&pool, 2, 7.0);
        pool.scatter_full(&[1, 2], 2, &batch);
        let mut out = Vec::new();
        pool.gather_full(&[1, 2], 2, &mut out);
        assert_eq!(out, batch);
        // reordering rows permutes the batch rows accordingly
        pool.gather_full(&[2, 1], 2, &mut out);
        assert_ne!(out, batch);
        let plane = 2 * 2 * 2;
        assert_eq!(out[0], batch[plane]); // row 0 now holds slot 2's data
    }

    #[test]
    fn layer_view_matches_full_view() {
        let mut pool = KvSlotPool::new(2, 3, 2, 2, 2);
        let batch = filled_batch(&pool, 2, 3.0);
        pool.scatter_full(&[0, 1], 2, &batch);
        let plane = 2 * 2 * 2;
        for l in 0..3 {
            let mut lv = Vec::new();
            pool.gather_layer(l, &[0, 1], 2, &mut lv);
            for c in 0..2 {
                for b in 0..2 {
                    let full = ((l * 2 + c) * 2 + b) * plane;
                    let lay = (c * 2 + b) * plane;
                    assert_eq!(&lv[lay..lay + plane], &batch[full..full + plane]);
                }
            }
        }
        // scatter one layer at a different bucket and read it back whole
        let mut lv = Vec::new();
        pool.gather_layer(1, &[1], 1, &mut lv);
        for x in lv.iter_mut() {
            *x += 100.0;
        }
        pool.scatter_layer(1, &[1], 1, &lv);
        let mut full = Vec::new();
        pool.gather_full(&[1], 1, &mut full);
        for c in 0..2 {
            for p in 0..plane {
                let batch_src = ((2 + c) * 2 + 1) * plane + p; // l=1, row 1
                assert_eq!(full[((2 + c)) * plane + p], batch[batch_src] + 100.0);
            }
        }
    }

    #[test]
    fn high_water_tracks_allocations() {
        let mut pool = KvSlotPool::new(4, 1, 1, 2, 1);
        assert_eq!(pool.high_water_slots, 0);
        let b = vec![0.0; pool.batch_elems(1)];
        pool.store_from_batch(0, &b, 1, 0);
        pool.store_from_batch(3, &b, 1, 0);
        pool.release(0);
        pool.store_from_batch(0, &b, 1, 0); // reuse, no new allocation
        assert_eq!(pool.high_water_slots, 2);
    }
}
