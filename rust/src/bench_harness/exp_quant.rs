//! Quantized expert-storage sweep (`cmoe bench --exp quant`): fp32 vs
//! int8 vs tiered expert serving on one synthetic converted layer.
//!
//! The expert-storage trait (`moe::ExpertStore`) makes precision and
//! placement a policy choice behind the grouped dispatcher. This sweep
//! measures what each storage policy buys and costs, artifact-free so
//! it runs on a fresh clone:
//!
//! * **bit-identity**: the quant-off [`TieredStore`] must produce
//!   f32-bit-identical routed output to the plain fp32 slice path
//!   (asserted, not just reported);
//! * **divergence**: relative L2 and worst per-element |Δ| of the int8
//!   band path vs fp32, checked against the analytic
//!   [`QuantizedFfn::divergence_bound`] composition per token at three
//!   input scales;
//! * **residency**: hit rate and prefetch/demotion churn of the
//!   cold-expert tier under synthetic routing drift;
//! * **grouped decode tok/s** through the real [`GroupedDispatcher`]
//!   hot path per storage policy, and the int8 speedup over fp32.
//!
//! Exported to the repo-root `BENCH_quant.json` so successive PRs can
//! diff the precision/placement frontier.

use crate::bench_harness::common::Ctx;
use crate::converter::{convert_ffn, ConvertOptions};
use crate::model::{model_config, FfnWeights, ModelWeights, MoeLayerWeights, MoeSpec};
use crate::moe::{route_tokens_dynamic, DynamicK, ExpertStore, GroupedRouting, TieredStore};
use crate::profiling::ActivationProfile;
use crate::quant::{compression_ratio, QuantizedFfn};
use crate::serving::{DispatchArena, GroupedDispatcher};
use crate::tensor::{self, Tensor};
use crate::util::table::{f, speedup, Table};
use crate::util::timer::measure;
use crate::util::Rng;
use anyhow::{ensure, Context as _, Result};
use std::time::Duration;

/// Converted spec for the sweep (same operating point as the dynk
/// sweep so the two trajectories are comparable).
const QUANT_SPEC: &str = "S2A4E8";
/// Tokens per measured wave.
const QUANT_BATCH: usize = 64;
/// Warm-set budget for the tiered row (of the spec's 8 routed experts).
const TIER_CAP: usize = 2;

/// The quantized-storage sweep as a bench-harness experiment
/// (`cmoe bench --exp quant`). Artifact-free; exports the repo-root
/// `BENCH_quant.json`.
pub fn quant_sweep(ctx: &mut Ctx) -> Result<Table> {
    let t = export_quant_json(ctx)?;
    ctx.save("quant", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Table + repo-root JSON export.
pub(super) fn export_quant_json(ctx: &Ctx) -> Result<Table> {
    let t = quant_sweep_table(ctx.seed, 3, Duration::from_millis(40))?;
    let root = crate::util::repo_root().unwrap_or_else(|| ctx.out_dir.clone());
    let path = root.join("BENCH_quant.json");
    std::fs::write(&path, t.to_json().pretty())
        .with_context(|| format!("write {}", path.display()))?;
    eprintln!("quant sweep exported to {}", path.display());
    Ok(t)
}

/// Synthetic converted layer (the dynk sweep's recipe).
fn quant_layer(rng: &mut Rng) -> Result<(MoeLayerWeights, MoeSpec)> {
    let d = 64usize;
    let d_ff = 512usize;
    let ffn = FfnWeights {
        w_gate: Tensor::randn(rng, &[d, d_ff], 0.4),
        w_up: Tensor::randn(rng, &[d, d_ff], 0.4),
        w_down: Tensor::randn(rng, &[d_ff, d], 0.4),
    };
    let xc = Tensor::randn(rng, &[256, d], 1.0);
    let h = tensor::swiglu_hidden(&xc, &ffn.w_gate, &ffn.w_up);
    let prof = ActivationProfile::from_hidden(&h, 10);
    let spec: MoeSpec = QUANT_SPEC.parse()?;
    let mut moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default())?;
    moe.compensation = None;
    Ok((moe, spec))
}

/// Steady-state grouped tok/s through `store` (arena pre-warmed; the
/// output scratch is call-local — allocation sits outside the timed
/// closure).
fn measure_tps<S: ExpertStore + ?Sized>(
    disp: &GroupedDispatcher,
    xn: &Tensor,
    routing: &GroupedRouting,
    store: &S,
    arena: &mut DispatchArena,
    min_iters: usize,
    min_time: Duration,
) -> f64 {
    let mut out = Tensor::zeros(&[xn.shape[0], xn.shape[1]]);
    let out = &mut out;
    out.data.fill(0.0);
    disp.forward(xn, routing, store, arena, out);
    let samples = measure(
        || {
            out.data.fill(0.0);
            disp.forward(xn, routing, store, arena, out);
            std::hint::black_box(&out);
        },
        min_iters,
        min_time,
    );
    let ns: Vec<f32> = samples.iter().map(|s| s.as_secs_f32() * 1e9).collect();
    let mean_ns = crate::util::stats::mean(&ns) as f64;
    if mean_ns <= 0.0 {
        0.0
    } else {
        QUANT_BATCH as f64 / (mean_ns / 1e9)
    }
}

/// Relative L2 distance of `y` from the fp32 oracle.
fn rel_l2(y: &Tensor, y_fp: &Tensor) -> f64 {
    let mut diff = y_fp.clone();
    for (a, b) in diff.data.iter_mut().zip(&y.data) {
        *a -= b;
    }
    diff.norm() as f64 / (y_fp.norm().max(1e-12) as f64)
}

/// Ctx-free sweep core.
pub fn quant_sweep_table(seed: u64, min_iters: usize, min_time: Duration) -> Result<Table> {
    let mut rng = Rng::new(seed ^ 0x0118);
    let (moe, spec) = quant_layer(&mut rng)?;
    let d = 64usize;
    let n_r = spec.routed();
    let m = moe.experts[0].hidden_dim();
    let xn = Tensor::randn(&mut rng, &[QUANT_BATCH, d], 1.0);

    let decisions = route_tokens_dynamic(&moe, &xn, DynamicK::fixed(), None);
    let mut routing = GroupedRouting::new(n_r);
    routing.rebuild(n_r, &decisions);
    let disp = GroupedDispatcher::new(d, m);
    let mut arena = DispatchArena::new();
    let mut out = Tensor::zeros(&[QUANT_BATCH, d]);

    // fp32 oracle through the plain slice path
    let mut y_fp = Tensor::zeros(&[QUANT_BATCH, d]);
    disp.forward(&xn, &routing, moe.experts.as_slice(), &mut arena, &mut y_fp);

    // compression ratio of the model at hand (actual quantized bytes,
    // scale overhead included): the synthetic layer's expert bands and
    // the tiny zoo model end-to-end
    let expert_q: Vec<QuantizedFfn> = moe.experts.iter().map(QuantizedFfn::quantize).collect();
    let band_fp32: usize = moe
        .experts
        .iter()
        .map(|e| (e.w_gate.numel() + e.w_up.numel() + e.w_down.numel()) * 4)
        .sum();
    let band_q: usize = expert_q.iter().map(|q| q.quantized_bytes()).sum();
    let band_ratio = band_fp32 as f64 / band_q as f64;
    let tiny = ModelWeights::random(&model_config("tiny")?, &mut rng);
    let model_ratio = compression_ratio(&tiny);

    let mut t = Table::new(
        &format!(
            "Quantized expert storage — fp32 vs int8 vs tiered through the grouped \
             dispatcher (synthetic {QUANT_SPEC} layer; int8 compression: expert bands \
             {band_ratio:.2}x, tiny zoo model {model_ratio:.2}x)"
        ),
        &["Config", "rel L2 vs fp32", "worst |d|", "bound", "residency", "tok/s", "vs fp32"],
    );

    // --- fp32 slice baseline ---
    let fp_tps =
        measure_tps(&disp, &xn, &routing, moe.experts.as_slice(), &mut arena, min_iters, min_time);
    t.row(vec![
        "fp32 slice".into(),
        f(0.0, 4),
        f(0.0, 5),
        "-".into(),
        "-".into(),
        f(fp_tps, 0),
        speedup(1.0),
    ]);

    // --- quant-off store: must be f32-bit-identical to the slice path ---
    let store_off = TieredStore::new(&moe.experts, false, TIER_CAP);
    out.data.fill(0.0);
    disp.forward(&xn, &routing, &store_off, &mut arena, &mut out);
    ensure!(
        out.data.iter().zip(&y_fp.data).all(|(a, b)| a.to_bits() == b.to_bits()),
        "quant-off TieredStore diverged from the fp32 slice path (must be bit-identical)"
    );
    let off_tps =
        measure_tps(&disp, &xn, &routing, &store_off, &mut arena, min_iters, min_time);
    t.row(vec![
        "fp32 store (quant off)".into(),
        f(0.0, 4),
        "bit-identical".into(),
        "-".into(),
        "-".into(),
        f(off_tps, 0),
        speedup(if fp_tps <= 0.0 { 1.0 } else { off_tps / fp_tps }),
    ]);

    // --- int8, everything resident ---
    let store_q = TieredStore::new(&moe.experts, true, n_r);
    out.data.fill(0.0);
    disp.forward(&xn, &routing, &store_q, &mut arena, &mut out);
    let (worst, bound) = divergence_vs_bound(&out, &y_fp, &xn, &decisions, &expert_q)?;
    let q_rel = rel_l2(&out, &y_fp);
    let q_tps = measure_tps(&disp, &xn, &routing, &store_q, &mut arena, min_iters, min_time);
    t.row(vec![
        format!("int8 resident (cap={n_r})"),
        f(q_rel, 4),
        f(worst as f64, 5),
        f(bound as f64, 5),
        "all warm".into(),
        f(q_tps, 0),
        speedup(if fp_tps <= 0.0 { 1.0 } else { q_tps / fp_tps }),
    ]);

    // --- int8 cold-expert tier under synthetic routing drift ---
    let mut store_t = TieredStore::new(&moe.experts, true, TIER_CAP);
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut prefetches = 0u64;
    let mut demotions = 0u64;
    let phase_a: Vec<usize> = (0..n_r).map(|e| if e < n_r / 2 { 8 } else { 0 }).collect();
    let phase_b: Vec<usize> = (0..n_r).map(|e| if e < n_r / 2 { 0 } else { 8 }).collect();
    for step in 0..24 {
        let counts = if step < 8 { &phase_a } else { &phase_b };
        let delta = store_t.note_step(counts);
        hits += delta.hits;
        misses += delta.misses;
        prefetches += delta.prefetches;
        demotions += delta.demotions;
    }
    let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
    out.data.fill(0.0);
    disp.forward(&xn, &routing, &store_t, &mut arena, &mut out);
    let t_rel = rel_l2(&out, &y_fp);
    let t_tps = measure_tps(&disp, &xn, &routing, &store_t, &mut arena, min_iters, min_time);
    t.row(vec![
        format!("int8 tiered (cap={TIER_CAP})"),
        f(t_rel, 4),
        "-".into(),
        "-".into(),
        format!("hit {:.0}% {prefetches}pf/{demotions}dm", hit_rate * 100.0),
        f(t_tps, 0),
        speedup(if fp_tps <= 0.0 { 1.0 } else { t_tps / fp_tps }),
    ]);

    // --- divergence sweep: the analytic bound must hold at every
    // input scale, not just the calibration-like one ---
    for scale in [0.5f32, 1.0, 2.0] {
        let mut xs = xn.clone();
        for v in xs.data.iter_mut() {
            *v *= scale;
        }
        let ds = route_tokens_dynamic(&moe, &xs, DynamicK::fixed(), None);
        routing.rebuild(n_r, &ds);
        let mut ys_fp = Tensor::zeros(&[QUANT_BATCH, d]);
        disp.forward(&xs, &routing, moe.experts.as_slice(), &mut arena, &mut ys_fp);
        out.data.fill(0.0);
        disp.forward(&xs, &routing, &store_q, &mut arena, &mut out);
        let (worst, bound) = divergence_vs_bound(&out, &ys_fp, &xs, &ds, &expert_q)?;
        t.row(vec![
            format!("int8 divergence @x{scale}"),
            f(rel_l2(&out, &ys_fp), 4),
            f(worst as f64, 5),
            f(bound as f64, 5),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
    }
    Ok(t)
}

/// Worst per-element |Δ| of the routed output vs fp32, checked per
/// token against the gate-weighted composition of each routed expert's
/// [`QuantizedFfn::divergence_bound`]. Returns `(worst, max bound)`.
fn divergence_vs_bound(
    y_q: &Tensor,
    y_fp: &Tensor,
    xn: &Tensor,
    decisions: &[crate::moe::GateDecision],
    experts_q: &[QuantizedFfn],
) -> Result<(f32, f32)> {
    let d = xn.shape[1];
    let mut worst = 0.0f32;
    let mut max_bound = 0.0f32;
    for (tk, dec) in decisions.iter().enumerate() {
        let row = &xn.data[tk * d..(tk + 1) * d];
        let bound_t: f32 = dec
            .experts
            .iter()
            .zip(&dec.gates)
            .map(|(&e, &g)| g.abs() * experts_q[e].divergence_bound(row))
            .sum();
        let worst_t = y_q.data[tk * d..(tk + 1) * d]
            .iter()
            .zip(&y_fp.data[tk * d..(tk + 1) * d])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        ensure!(
            worst_t <= bound_t * 1.01 + 1e-4,
            "token {tk}: int8 divergence {worst_t} exceeds analytic bound {bound_t}"
        );
        worst = worst.max(worst_t);
        max_bound = max_bound.max(bound_t);
    }
    Ok((worst, max_bound))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_table_covers_every_storage_policy_and_bounds_hold() {
        let t = quant_sweep_table(0xBEEF, 1, Duration::from_millis(1)).unwrap();
        let j = t.to_json().pretty();
        for label in ["fp32 slice", "quant off", "int8 resident", "int8 tiered", "divergence @x2"] {
            assert!(j.contains(label), "missing sweep row {label}");
        }
        // bit-identity and the per-token bound checks are enforced
        // inside the sweep itself — reaching here means they held
        assert!(j.contains("bit-identical"));
    }
}
