//! Figures 1–2: the activation-pattern observations that motivate CMoE.

use crate::bench_harness::common::{Ctx, CALIB_EXAMPLES, KA};
use crate::data::corpus::Domain;
use crate::util::table::Table;
use anyhow::Result;

/// Figure 1: distribution of FFN hidden activations — sharply peaked at
/// zero (paper §3.1). We report the histogram plus the mass within
/// small |h| bands for every layer.
pub fn fig1(ctx: &mut Ctx) -> Result<Table> {
    let profiles = ctx.profiles(Domain::Markov, CALIB_EXAMPLES, KA)?;
    let mut t = Table::new(
        "Figure 1 — FFN hidden state distribution (small, markov calib)",
        &["Layer", "frac |h|<0.01", "frac |h|<0.05", "frac |h|<0.1", "p99.9 |h|"],
    );
    for (l, p) in profiles.iter().enumerate() {
        let abs: Vec<f32> = p.h_sample.iter().map(|v| v.abs()).collect();
        t.row(vec![
            format!("{l}"),
            format!("{:.3}", p.sparsity_fraction(0.01)),
            format!("{:.3}", p.sparsity_fraction(0.05)),
            format!("{:.3}", p.sparsity_fraction(0.1)),
            format!("{:.3}", crate::util::stats::percentile(&abs, 99.9)),
        ]);
    }
    // ASCII histogram of layer 0 for the figure itself
    let hist = profiles[0].activation_histogram(25);
    println!("{}", hist.ascii(50));
    ctx.save("fig1", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Figure 2: the bimodal activation-rate distribution — most neurons
/// rare, a subset always-on (paper §3.2).
pub fn fig2(ctx: &mut Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Figure 2 — neuron activation-rate distribution",
        &["K_a", "Layer", "median μ", "frac μ>0.5", "frac μ>0.9", "bimodality (>5/9 ⇒ bimodal)"],
    );
    // K_a = 10 is the conversion setting; the larger K_a mirrors the
    // paper's visualization note (K_a = 1000 of 11008 ≈ 9% of d_h; here
    // 48 of 512).
    for ka in [KA, 48] {
        let profiles = ctx.profiles(Domain::Markov, CALIB_EXAMPLES, ka)?;
        for (l, p) in profiles.iter().enumerate() {
            let mu = p.rates();
            t.row(vec![
                format!("{ka}"),
                format!("{l}"),
                format!("{:.4}", crate::util::stats::percentile(&mu, 50.0)),
                format!("{:.4}", mu.iter().filter(|&&m| m > 0.5).count() as f64 / mu.len() as f64),
                format!("{:.4}", mu.iter().filter(|&&m| m > 0.9).count() as f64 / mu.len() as f64),
                format!("{:.3}", p.rate_bimodality()),
            ]);
        }
        if ka == 48 {
            let hist = profiles[0].rate_histogram(20);
            println!("{}", hist.ascii(50));
        }
    }
    ctx.save("fig2", std::slice::from_ref(&t))?;
    Ok(t)
}
