//! Dynamic-activation sweep (`cmoe bench --exp dynk`): serve-time
//! operating points on one synthetic converted layer.
//!
//! ROADMAP item 4 makes the expert count per token a runtime quantity
//! — per-token dynamic-k (router-entropy thresholds) and per-row
//! effort-tier caps (activation ratios, the paper's 25%/75% points).
//! This sweep measures what each operating point buys and costs, all
//! artifact-free so it runs on a fresh clone:
//!
//! * **mean k/token** and the implied **activated fraction** of routed
//!   experts (the FLOP driver — grouped dispatch gathers `Σ_t k_t`
//!   rows instead of `q · N_k`);
//! * a **logit-divergence proxy**: relative L2 distance of the dynamic
//!   forward from the fixed-k forward on the same tokens (the fixed
//!   row must read exactly 0 — the threshold-0 bit-identity that
//!   `rust/tests/dynamic_k.rs` pins at the routing level);
//! * **grouped decode tok/s** through the real [`GroupedDispatcher`]
//!   hot path at that operating point, and its speedup over fixed-k.
//!
//! Exported to the repo-root `BENCH_dynk.json` (also refreshed by
//! `cmoe bench --exp serving`) so successive PRs can diff the
//! quality/compute frontier alongside the serving trajectory.

use crate::bench_harness::common::Ctx;
use crate::converter::{convert_ffn, ConvertOptions};
use crate::model::{FfnWeights, MoeLayerWeights, MoeSpec};
use crate::moe::{
    k_for_ratio, moe_ffn_forward_dynamic, route_tokens_dynamic, DynamicK, GroupedRouting,
};
use crate::profiling::ActivationProfile;
use crate::serving::{DispatchArena, GroupedDispatcher};
use crate::tensor::{self, Tensor};
use crate::util::table::{f, speedup, Table};
use crate::util::timer::measure;
use crate::util::Rng;
use anyhow::{Context as _, Result};
use std::time::Duration;

/// Converted spec for the sweep: N_k = 4 of 8 routed experts, so the
/// tier ratios 0.75/0.25 land on k = 3 and k = 1 — the paper's two
/// serving operating points.
const DYNK_SPEC: &str = "S2A4E8";
/// Tokens per measured wave.
const DYNK_BATCH: usize = 64;

/// One serve-time activation operating point.
struct OpPoint {
    label: &'static str,
    dk: DynamicK,
    /// Uniform per-row k cap (effort tier), `None` = untiered.
    ratio: Option<f32>,
}

fn operating_points() -> Vec<OpPoint> {
    let fixed = DynamicK::fixed();
    vec![
        OpPoint { label: "fixed top-k", dk: fixed, ratio: None },
        OpPoint { label: "dynk h=0.25", dk: DynamicK { threshold: 0.25, k_min: 1 }, ratio: None },
        OpPoint { label: "dynk h=0.50", dk: DynamicK { threshold: 0.50, k_min: 1 }, ratio: None },
        OpPoint { label: "dynk h=0.75", dk: DynamicK { threshold: 0.75, k_min: 1 }, ratio: None },
        OpPoint { label: "tier 75%", dk: fixed, ratio: Some(0.75) },
        OpPoint { label: "tier 25%", dk: fixed, ratio: Some(0.25) },
    ]
}

/// The dynamic-activation sweep as a bench-harness experiment
/// (`cmoe bench --exp dynk`). Artifact-free; exports the repo-root
/// `BENCH_dynk.json` for the cross-PR quality/compute trajectory.
pub fn dynk_sweep(ctx: &mut Ctx) -> Result<Table> {
    let t = export_dynk_json(ctx)?;
    ctx.save("dynk", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Table + repo-root JSON export, shared with `--exp serving` (which
/// refreshes every serving-trajectory artifact in one run).
pub(super) fn export_dynk_json(ctx: &Ctx) -> Result<Table> {
    let t = dynk_sweep_table(ctx.seed, 3, Duration::from_millis(40))?;
    let root = crate::util::repo_root().unwrap_or_else(|| ctx.out_dir.clone());
    let path = root.join("BENCH_dynk.json");
    std::fs::write(&path, t.to_json().pretty())
        .with_context(|| format!("write {}", path.display()))?;
    eprintln!("dynk sweep exported to {}", path.display());
    Ok(t)
}

/// Synthetic converted layer for the sweep (same recipe as the
/// dispatch sweep, smaller so the whole table stays sub-second).
fn dynk_layer(rng: &mut Rng) -> Result<(MoeLayerWeights, MoeSpec)> {
    let d = 64usize;
    let d_ff = 512usize;
    let ffn = FfnWeights {
        w_gate: Tensor::randn(rng, &[d, d_ff], 0.4),
        w_up: Tensor::randn(rng, &[d, d_ff], 0.4),
        w_down: Tensor::randn(rng, &[d_ff, d], 0.4),
    };
    let xc = Tensor::randn(rng, &[256, d], 1.0);
    let h = tensor::swiglu_hidden(&xc, &ffn.w_gate, &ffn.w_up);
    let prof = ActivationProfile::from_hidden(&h, 10);
    let spec: MoeSpec = DYNK_SPEC.parse()?;
    let mut moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default())?;
    moe.compensation = None;
    Ok((moe, spec))
}

/// Ctx-free sweep core (deterministic routing/divergence columns; the
/// tok/s columns are wall-time through the grouped dispatcher).
pub fn dynk_sweep_table(seed: u64, min_iters: usize, min_time: Duration) -> Result<Table> {
    let mut rng = Rng::new(seed ^ 0xD1CE);
    let (moe, spec) = dynk_layer(&mut rng)?;
    let d = 64usize;
    let n_k = spec.active;
    let n_r = spec.routed();
    let xn = Tensor::randn(&mut rng, &[DYNK_BATCH, d], 1.0);

    // fixed-k oracle for the divergence column
    let (y_fixed, _) = moe_ffn_forward_dynamic(&moe, &xn, DynamicK::fixed(), None);
    let norm_fixed = y_fixed.norm().max(1e-12);

    let m = moe.experts[0].hidden_dim();
    let disp = GroupedDispatcher::new(d, m);
    let mut arena = DispatchArena::new();
    let mut routing = GroupedRouting::new(n_r);

    let mut t = Table::new(
        "Dynamic activation sweep — per-token dynamic-k and effort-tier \
         operating points vs the fixed-k oracle (synthetic S2A4E8 layer)",
        &[
            "Point",
            "mean k/tok",
            "act frac",
            "routed rows",
            "rel L2 vs fixed",
            "grouped tok/s",
            "vs fixed",
        ],
    );

    let mut fixed_tps = 0.0f64;
    for p in operating_points() {
        let caps: Option<Vec<usize>> =
            p.ratio.map(|r| vec![k_for_ratio(r, n_k); DYNK_BATCH]);
        let decisions = route_tokens_dynamic(&moe, &xn, p.dk, caps.as_deref());
        let rows: usize = decisions.iter().map(|dec| dec.experts.len()).sum();
        let mean_k = rows as f64 / DYNK_BATCH as f64;

        let (y, _) = moe_ffn_forward_dynamic(&moe, &xn, p.dk, caps.as_deref());
        let mut diff = y_fixed.clone();
        for (a, b) in diff.data.iter_mut().zip(&y.data) {
            *a -= b;
        }
        let rel = diff.norm() as f64 / norm_fixed as f64;
        if p.label == "fixed top-k" {
            anyhow::ensure!(rel == 0.0, "fixed operating point diverged from itself: {rel}");
        }

        // grouped-dispatch hot path at this operating point: warm the
        // arena, then measure the steady state (rebuild + forward)
        let mut out = Tensor::zeros(&[DYNK_BATCH, d]);
        routing.rebuild(n_r, &decisions);
        disp.forward(&xn, &routing, &moe.experts, &mut arena, &mut out);
        let samples = measure(
            || {
                routing.rebuild(n_r, &decisions);
                out.data.fill(0.0);
                disp.forward(&xn, &routing, &moe.experts, &mut arena, &mut out);
                std::hint::black_box(&out);
            },
            min_iters,
            min_time,
        );
        let ns: Vec<f32> = samples.iter().map(|s| s.as_secs_f32() * 1e9).collect();
        let mean_ns = crate::util::stats::mean(&ns) as f64;
        let tps = if mean_ns <= 0.0 { 0.0 } else { DYNK_BATCH as f64 / (mean_ns / 1e9) };
        if p.label == "fixed top-k" {
            fixed_tps = tps;
        }

        t.row(vec![
            p.label.into(),
            f(mean_k, 2),
            format!("{:.0}%", mean_k / n_k as f64 * 100.0),
            rows.to_string(),
            f(rel, 4),
            f(tps, 0),
            speedup(if fixed_tps <= 0.0 { 1.0 } else { tps / fixed_tps }),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynk_table_has_all_operating_points_and_fixed_is_exact() {
        let t = dynk_sweep_table(0xC0DE, 1, Duration::from_millis(1)).unwrap();
        let j = t.to_json().pretty();
        for p in operating_points() {
            assert!(j.contains(p.label), "missing operating point {}", p.label);
        }
        // the fixed row's divergence column is exactly zero and the
        // tier caps land on the paper's k = 3 / k = 1 points
        let spec: MoeSpec = DYNK_SPEC.parse().unwrap();
        assert_eq!(k_for_ratio(0.75, spec.active), 3);
        assert_eq!(k_for_ratio(0.25, spec.active), 1);
    }
}
