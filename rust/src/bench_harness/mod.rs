//! The experiment harness: one runner per table/figure of the paper's
//! evaluation (docs/ARCHITECTURE.md, "Build & verification", maps the
//! harness into the repo's layers).
//!
//! `cmoe bench --exp table1` (or `fig2`, `all`, …) regenerates the
//! corresponding table/figure rows on this testbed's substitute
//! workloads; results print as aligned text and are exported to
//! `results/<exp>.json`.

pub mod common;
pub mod runner;
mod exp_ablate;
mod exp_figs;
mod exp_quality;
mod exp_efficiency;
pub mod exp_dynk;
pub mod exp_quant;
pub mod exp_serving;
pub mod exp_slo;

use crate::util::table::Table;
use anyhow::{bail, Result};
use common::Ctx;

/// Every experiment id, in paper order; `dispatch` (the grouped expert
/// dispatch sweep), `serving` (continuous-vs-waves scheduling sweep),
/// `prefix` (shared-system-prompt KV page sharing sweep), `slo`
/// (priority/preemption/shed-load burst sweep), `dynk` (dynamic-k /
/// effort-tier activation operating points) and `quant` (fp32 vs int8
/// vs tiered expert storage), all artifact-free, ride at the end.
pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig1", "fig2", "table1", "table2", "table3", "table4", "table5", "table6", "table7",
    "table8", "table9", "table10", "table11", "fig4", "fig5", "fig6", "dispatch", "serving",
    "prefix", "slo", "dynk", "quant",
];

/// Run one experiment by id.
pub fn run(exp: &str, ctx: &mut Ctx) -> Result<Vec<Table>> {
    Ok(match exp {
        "fig1" => vec![exp_figs::fig1(ctx)?],
        "fig2" => vec![exp_figs::fig2(ctx)?],
        "fig4" => vec![exp_quality::fig4(ctx)?],
        "fig5" => vec![exp_serving::fig5(ctx)?],
        "fig6" => vec![exp_quality::fig6(ctx)?],
        "table1" => vec![exp_quality::table1(ctx)?],
        "table2" => vec![exp_quality::table2(ctx)?],
        "table3" => vec![exp_quality::table3(ctx)?],
        "table4" => vec![exp_quality::table4(ctx)?],
        "table5" => vec![exp_quality::table5(ctx)?],
        "table6" => vec![exp_efficiency::table6(ctx)?],
        "table7" => vec![exp_efficiency::table7(ctx)?],
        "table8" => vec![exp_efficiency::table8(ctx)?],
        "table9" => vec![exp_serving::table9(ctx)?],
        "dispatch" => vec![exp_serving::dispatch_sweep(ctx)?],
        "serving" => vec![exp_serving::serving_sweep(ctx)?],
        "prefix" => vec![exp_serving::prefix_sweep(ctx)?],
        "slo" => vec![exp_slo::slo_sweep(ctx)?],
        "dynk" => vec![exp_dynk::dynk_sweep(ctx)?],
        "quant" => vec![exp_quant::quant_sweep(ctx)?],
        "table10" => vec![exp_quality::table10(ctx)?],
        "table11" => vec![exp_quality::table11(ctx)?],
        "ablate" => vec![
            exp_ablate::ablate_assignment(ctx)?,
            exp_ablate::ablate_ka(ctx)?,
            exp_ablate::ablate_quant(ctx)?,
        ],
        "all" => {
            let mut out = Vec::new();
            for e in ALL_EXPERIMENTS {
                eprintln!("== running {e} ==");
                out.extend(run(e, ctx)?);
            }
            out
        }
        _ => bail!("unknown experiment '{exp}' (available: {ALL_EXPERIMENTS:?} or 'all')"),
    })
}
