//! Design-choice ablations beyond the paper's tables (the substitutions
//! docs/ARCHITECTURE.md motivates):
//! tables): exact JV balanced assignment vs greedy rebalancing, the
//! ATopK K_a sweep, calibration-size scaling of the conversion cost,
//! and int8 quantization composition (§6).

use crate::bench_harness::common::{Ctx, CALIB_EXAMPLES, CALIB_SEQ, KA};
use crate::converter::{convert_ffn, reconstruction_error, ConvertOptions};
use crate::data::corpus::Domain;
use crate::eval::forward::DenseForward;
use crate::eval::perplexity;
use crate::model::MoeSpec;
use crate::util::table::{f, Table};
use crate::util::Timer;
use anyhow::Result;

/// Ablation A: exact Jonker–Volgenant assignment vs the greedy
/// rebalance, on reconstruction error and conversion time.
pub fn ablate_assignment(ctx: &mut Ctx) -> Result<Table> {
    let dense = ctx.model()?.clone();
    let profiles = ctx.profiles(Domain::Markov, CALIB_EXAMPLES, KA)?;
    let calib = ctx.calib_tokens(Domain::Markov, CALIB_EXAMPLES);
    let probe = DenseForward::new(&dense).capture_ffn_inputs(&calib[..CALIB_SEQ]);
    let spec: MoeSpec = "S3A3E8".parse()?;

    let mut t = Table::new(
        "Ablation — balanced assignment: exact JV vs greedy",
        &["Assignment", "Layer", "Recon. error", "Convert time"],
    );
    for (label, exact) in [("JV (exact)", true), ("Greedy", false)] {
        for l in 0..dense.config.n_layers {
            let ffn = dense.dense_ffn(l);
            let opts = ConvertOptions { exact_assignment: exact, ..Default::default() };
            let timer = Timer::start();
            let moe = convert_ffn(ffn, &profiles[l], &spec, &opts)?;
            let dt = timer.total();
            t.row(vec![
                label.into(),
                format!("{l}"),
                f(reconstruction_error(ffn, &moe, &probe[l]), 4),
                crate::util::timer::fmt_duration(dt),
            ]);
        }
    }
    ctx.save("ablate_assignment", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Ablation B: K_a sweep — how the ATopK width changes the partition
/// quality (reconstruction at fixed sparsity).
pub fn ablate_ka(ctx: &mut Ctx) -> Result<Table> {
    let dense = ctx.model()?.clone();
    let calib = ctx.calib_tokens(Domain::Markov, CALIB_EXAMPLES);
    let probe = DenseForward::new(&dense).capture_ffn_inputs(&calib[..CALIB_SEQ]);
    let spec: MoeSpec = "S3A3E8".parse()?;
    let mut t = Table::new(
        "Ablation — ATopK K_a sweep (layer 0, S3A3E8)",
        &["K_a", "Recon. error", "Rate bimodality"],
    );
    for ka in [4usize, 10, 24, 48, 96] {
        let profiles = ctx.profiles(Domain::Markov, CALIB_EXAMPLES, ka)?;
        let ffn = dense.dense_ffn(0);
        let moe = convert_ffn(ffn, &profiles[0], &spec, &ConvertOptions::default())?;
        t.row(vec![
            format!("{ka}"),
            f(reconstruction_error(ffn, &moe, &probe[0]), 4),
            f(profiles[0].rate_bimodality(), 3),
        ]);
    }
    ctx.save("ablate_ka", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Ablation C: int8 weight quantization composed with CMoE (§6).
pub fn ablate_quant(ctx: &mut Ctx) -> Result<Table> {
    let dense = ctx.model()?.clone();
    let ours = ctx.convert(&"S3A3E8".parse()?)?;
    let toks = ctx.eval_tokens(Domain::Markov, 4096);
    let mut t = Table::new(
        "Ablation — int8 PTQ composition (§6)",
        &["Model", "Precision", "PPL markov"],
    );
    for (name, m) in [("Dense", &dense), ("CMoE 25%", &ours)] {
        t.row(vec![name.into(), "f32".into(), f(perplexity(m, &toks, CALIB_SEQ), 3)]);
        let q = crate::quant::quantize_model(m);
        t.row(vec![name.into(), "int8 (sim.)".into(), f(perplexity(&q, &toks, CALIB_SEQ), 3)]);
    }
    ctx.save("ablate_quant", std::slice::from_ref(&t))?;
    Ok(t)
}
