//! SLO sweep (`cmoe bench --exp slo`): overload survival under a
//! Poisson burst — priority classes + deadline-urgent preemption +
//! bounded admission vs the FIFO-only scheduler on the **identical**
//! arrival trace.
//!
//! The workload is an open-loop Poisson trace with a burst window
//! (λ jumps ~8× for 20 steps): 20% High requests with tight step
//! deadlines, 50% Normal with loose deadlines, 30% Low with none.
//! The FIFO baseline erases class and deadline at submission (the
//! pre-ISSUE-6 scheduler, unbounded queue, no preemption) but is
//! still *scored* against the original deadlines; the SLO policy
//! keeps them and runs with park-mode preemption, anti-starvation
//! aging, and a bounded queue that degrades then sheds.
//!
//! Reported per policy × class: submissions, completions, sheds,
//! deadline-miss rate among completions, combined miss-or-shed rate
//! (the goodput complement), wait percentiles, and the policy's
//! preemption/degrade counters. Artifact-free; exports the repo-root
//! `BENCH_slo.json` for the cross-PR trajectory.

use crate::bench_harness::common::Ctx;
use crate::bench_harness::exp_serving::poisson;
use crate::serving::{
    stub_reference, BatcherConfig, Clock, ContinuousSession, GenParams, PreemptMode, Priority,
    Request, StubForward, SubmitOutcome,
};
use crate::util::stats::percentile;
use crate::util::table::{f, Table};
use crate::util::Rng;
use anyhow::{ensure, Context as _, Result};
use std::time::Duration;

const SLO_VOCAB: usize = 23;
const SLO_KV_CAP: usize = 96;
/// Small bucket ladder (pool 8): the burst must actually oversubscribe
/// the pool for scheduling policy to matter.
const SLO_BUCKETS: &[usize] = &[1, 4, 8];
/// Burst window in scheduler steps, and the arrival rates outside /
/// inside it.
const BURST_STEPS: std::ops::Range<u64> = 10..30;
const LAMBDA_BASE: f64 = 0.8;
const LAMBDA_BURST: f64 = 6.0;

/// The pre-ISSUE-6 scheduler: one FIFO class, unbounded, no preemption.
fn fifo_cfg() -> BatcherConfig {
    BatcherConfig { buckets: SLO_BUCKETS.to_vec(), max_wait: Duration::ZERO, ..Default::default() }
}

/// The overload-survival policy under test.
fn slo_cfg() -> BatcherConfig {
    BatcherConfig {
        buckets: SLO_BUCKETS.to_vec(),
        max_wait: Duration::ZERO,
        queue_cap: Some(16),
        degrade_margin: 8,
        age_promote_steps: 48,
        preempt: PreemptMode::Park,
        ..Default::default()
    }
}

/// Mixed-class Poisson burst trace (ascending arrival steps).
fn gen_slo_trace(rng: &mut Rng, n_req: usize) -> Vec<(u64, Request)> {
    let mut out = Vec::with_capacity(n_req);
    let mut step = 0u64;
    while out.len() < n_req {
        let lambda = if BURST_STEPS.contains(&step) { LAMBDA_BURST } else { LAMBDA_BASE };
        for _ in 0..poisson(rng, lambda) {
            if out.len() >= n_req {
                break;
            }
            let id = out.len() as u64;
            let prompt: Vec<usize> =
                (0..1 + rng.below(12)).map(|_| rng.below(SLO_VOCAB)).collect();
            let params = GenParams {
                max_new_tokens: 2 + rng.below(24),
                temperature: 0.0,
                seed: id ^ 0x510,
                stop_token: if rng.f32() < 0.15 { Some(rng.below(SLO_VOCAB)) } else { None },
            };
            let r = Request::new(id, prompt, params);
            let r = match rng.below(10) {
                0 | 1 => r
                    .with_priority(Priority::High)
                    .with_deadline_steps(2 + rng.below(4) as u64),
                2..=6 => r
                    .with_priority(Priority::Normal)
                    .with_deadline_steps(8 + rng.below(16) as u64),
                _ => r.with_priority(Priority::Low),
            };
            out.push((step, r));
        }
        step += 1;
    }
    out
}

#[derive(Default)]
struct ClassStats {
    submitted: usize,
    completed: usize,
    shed: usize,
    /// Completions admitted later than their (original) deadline.
    misses: usize,
    waits: Vec<f32>,
}

impl ClassStats {
    fn miss_rate(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.misses as f64 / self.completed as f64
    }

    /// Goodput complement: requests that either missed their deadline
    /// or never ran at all, over everything submitted in the class.
    fn miss_or_shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        (self.misses + self.shed) as f64 / self.submitted as f64
    }
}

#[derive(Default)]
struct PolicyOutcome {
    class_stats: [ClassStats; 3],
    preemptions: u64,
    resumed: u64,
    shed_total: u64,
    degraded: u64,
    max_pending: usize,
    token_mismatches: usize,
}

/// Replay `trace` under `cfg`. With `strip` the submission erases
/// class and deadline (the FIFO baseline) — scoring always uses the
/// original request, so both policies are graded on the same SLOs.
fn run_policy(trace: &[(u64, Request)], cfg: BatcherConfig, strip: bool) -> Result<PolicyOutcome> {
    let pool = *cfg.buckets.iter().max().unwrap();
    let mut sess = ContinuousSession::with_clock(
        cfg,
        StubForward::new(pool, SLO_VOCAB, SLO_KV_CAP),
        Clock::manual(),
    )?;
    let mut out = PolicyOutcome::default();
    let mut next = 0usize;
    while next < trace.len() || !sess.is_idle() {
        while next < trace.len() && trace[next].0 <= sess.step_index() {
            let r = &trace[next].1;
            out.class_stats[r.priority.index()].submitted += 1;
            let submit = if strip {
                let mut c = r.clone();
                c.priority = Priority::Normal;
                c.deadline_steps = None;
                c
            } else {
                r.clone()
            };
            if let SubmitOutcome::Rejected(_) = sess.enqueue(submit) {
                out.class_stats[r.priority.index()].shed += 1;
            }
            next += 1;
        }
        for res in sess.step()? {
            let orig = &trace.iter().find(|(_, q)| q.id == res.id).unwrap().1;
            let stats = &mut out.class_stats[orig.priority.index()];
            stats.completed += 1;
            stats.waits.push(res.queued_steps as f32);
            if let Some(d) = orig.deadline_steps {
                if res.queued_steps > d {
                    stats.misses += 1;
                }
            }
            if res.tokens != stub_reference(orig, SLO_VOCAB, SLO_KV_CAP) {
                out.token_mismatches += 1;
            }
        }
        out.max_pending = out.max_pending.max(sess.pending());
        ensure!(sess.step_index() < 10_000_000, "slo sweep failed to converge");
    }
    ensure!(sess.take_failures().is_empty(), "faultless trace produced request failures");
    let m = sess.metrics();
    out.preemptions = m.preemptions;
    out.resumed = m.resumed;
    out.shed_total = m.shed_requests;
    out.degraded = m.degraded_admissions;
    Ok(out)
}

/// Run both policies on one seeded trace.
fn slo_compare(seed: u64, n_req: usize) -> Result<(PolicyOutcome, PolicyOutcome)> {
    let mut rng = Rng::new(seed ^ 0x510);
    let trace = gen_slo_trace(&mut rng, n_req);
    let fifo = run_policy(&trace, fifo_cfg(), true)?;
    let slo = run_policy(&trace, slo_cfg(), false)?;
    Ok((fifo, slo))
}

/// Ctx-free sweep core (unit-testable on a fresh clone).
pub fn slo_sweep_table(seed: u64, n_req: usize) -> Result<Table> {
    let (fifo, slo) = slo_compare(seed, n_req)?;
    let mut t = Table::new(
        "SLO sweep — priority + preemption + bounded admission vs FIFO under a Poisson burst",
        &[
            "Policy", "Class", "Submitted", "Done", "Shed", "Miss%", "Miss+Shed%", "p50 wait",
            "p99 wait", "Preempt", "Resumed", "Degraded",
        ],
    );
    for (name, o) in [("fifo", &fifo), ("slo", &slo)] {
        for p in Priority::ALL {
            let s = &o.class_stats[p.index()];
            t.row(vec![
                name.into(),
                p.name().into(),
                s.submitted.to_string(),
                s.completed.to_string(),
                s.shed.to_string(),
                format!("{:.1}%", s.miss_rate() * 100.0),
                format!("{:.1}%", s.miss_or_shed_rate() * 100.0),
                f(percentile(&s.waits, 50.0) as f64, 1),
                f(percentile(&s.waits, 99.0) as f64, 1),
                o.preemptions.to_string(),
                o.resumed.to_string(),
                o.degraded.to_string(),
            ]);
        }
    }
    Ok(t)
}

/// The bench-harness entry point: print + `results/slo.json` +
/// repo-root `BENCH_slo.json` (cross-PR trajectory file).
pub fn slo_sweep(ctx: &mut Ctx) -> Result<Table> {
    let t = slo_sweep_table(ctx.seed, 160)?;
    ctx.save("slo", std::slice::from_ref(&t))?;
    let root = crate::util::repo_root().unwrap_or_else(|| ctx.out_dir.clone());
    let path = root.join("BENCH_slo.json");
    std::fs::write(&path, t.to_json().pretty())
        .with_context(|| format!("write {}", path.display()))?;
    eprintln!("slo sweep exported to {}", path.display());
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The ISSUE-6 acceptance comparison: on the same burst trace the
    /// SLO policy strictly improves the high-priority goodput
    /// complement, preempts and resumes observably, sheds observably
    /// (bounded queue), and stays token-exact for every completion.
    #[test]
    fn slo_policy_strictly_improves_high_class_under_burst() {
        let (fifo, slo) = slo_compare(0xC0DE, 120).unwrap();
        // both policies completed work and neither corrupted a stream
        assert_eq!(fifo.token_mismatches, 0, "FIFO policy diverged from reference");
        assert_eq!(slo.token_mismatches, 0, "SLO policy diverged from reference");
        let fifo_high = &fifo.class_stats[Priority::High.index()];
        let slo_high = &slo.class_stats[Priority::High.index()];
        assert!(fifo_high.submitted > 0 && fifo_high.submitted == slo_high.submitted);
        // the headline acceptance bar: strict improvement for High
        assert!(
            slo_high.miss_or_shed_rate() < fifo_high.miss_or_shed_rate(),
            "SLO policy must strictly improve high-priority miss-or-shed: {:.3} vs {:.3}",
            slo_high.miss_or_shed_rate(),
            fifo_high.miss_or_shed_rate()
        );
        // the machinery demonstrably ran: preemption with full resume…
        assert!(slo.preemptions > 0, "burst never triggered preemption");
        assert_eq!(slo.resumed, slo.preemptions, "a preempted victim never resumed");
        assert_eq!(fifo.preemptions, 0, "FIFO baseline must not preempt");
        // …and bounded admission: FIFO absorbs everything, SLO sheds
        let cap_bound = 3 * (16 + 8);
        assert!(fifo.shed_total == 0, "unbounded FIFO baseline shed load");
        assert!(slo.shed_total > 0, "burst never exercised shed-load");
        assert!(
            slo.max_pending <= cap_bound,
            "queue exceeded its bound: {} > {cap_bound}",
            slo.max_pending
        );
        let shed_by_class: usize = slo.class_stats.iter().map(|s| s.shed).sum();
        assert_eq!(shed_by_class as u64, slo.shed_total, "shed accounting disagrees");
    }
}
