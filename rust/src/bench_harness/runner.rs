//! Criterion-style micro-bench runner (criterion itself is unavailable
//! offline). Used by the `cargo bench` targets in `rust/benches/`.

use crate::util::timer::{fmt_duration, measure};
use std::time::Duration;

/// One benchmark group printer.
pub struct BenchRunner {
    group: String,
    min_iters: usize,
    min_time: Duration,
}

impl BenchRunner {
    pub fn new(group: &str) -> Self {
        BenchRunner {
            group: group.to_string(),
            min_iters: 10,
            min_time: Duration::from_millis(300),
        }
    }

    pub fn with_budget(mut self, min_iters: usize, min_time: Duration) -> Self {
        self.min_iters = min_iters;
        self.min_time = min_time;
        self
    }

    /// Time a closure; prints mean ± std, median, and throughput if
    /// `items_per_iter` is given.
    pub fn bench<F: FnMut()>(&self, name: &str, items_per_iter: Option<f64>, f: F) {
        let samples = measure(f, self.min_iters, self.min_time);
        let ns: Vec<f32> = samples.iter().map(|d| d.as_secs_f32() * 1e9).collect();
        let mean = crate::util::stats::mean(&ns);
        let sd = crate::util::stats::std_dev(&ns);
        let p50 = crate::util::stats::percentile(&ns, 50.0);
        let mean_d = Duration::from_nanos(mean as u64);
        let p50_d = Duration::from_nanos(p50 as u64);
        let thru = items_per_iter
            .map(|items| format!("  {:>10.1} items/s", items / (mean as f64 / 1e9)))
            .unwrap_or_default();
        println!(
            "{}/{name:<32} {:>10} ±{:>4.1}%  p50 {:>10}  n={}{}",
            self.group,
            fmt_duration(mean_d),
            if mean > 0.0 { sd / mean * 100.0 } else { 0.0 },
            fmt_duration(p50_d),
            samples.len(),
            thru,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_prints() {
        let r = BenchRunner::new("test").with_budget(3, Duration::from_millis(1));
        r.bench("noop", Some(1.0), || {
            std::hint::black_box(42);
        });
    }
}
