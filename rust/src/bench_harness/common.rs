//! Shared experiment context: the pretrained checkpoint, calibration
//! profiles, converted-model cache, task suites and corpora — so each
//! experiment runner stays small and the expensive pieces are computed
//! once.

use crate::baselines;
use crate::converter::{convert_model, ConvertOptions, ConvertedModel};
use crate::data::corpus::{gen_corpus, CorpusSpec, Domain};
use crate::data::tasks_gen::{gen_choice_tasks, TaskFamily};
use crate::data::encode;
use crate::eval::forward::DenseForward;
use crate::eval::tasks::TaskSuite;
use crate::model::{LayerFfn, ModelWeights, MoeLayerWeights, MoeSpec};
use crate::profiling::{profile_dense_model, ActivationProfile};
use crate::util::json::Json;
use anyhow::{Context as _, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Default calibration setup, mirroring the paper's §5.1 (8 examples,
/// K_a = 10; our sequences are 256 tokens at `small`'s max_seq).
pub const CALIB_EXAMPLES: usize = 8;
pub const CALIB_SEQ: usize = 256;
pub const KA: usize = 10;

/// Experiment context.
pub struct Ctx {
    pub artifact_dir: PathBuf,
    pub out_dir: PathBuf,
    pub model_name: String,
    model: Option<ModelWeights>,
    profiles: HashMap<(String, usize, usize), Vec<ActivationProfile>>, // (domain, n, ka)
    converted: HashMap<String, ModelWeights>,
    runtime: Option<std::sync::Arc<crate::runtime::XlaRuntime>>,
    pub seed: u64,
}

impl Ctx {
    pub fn new(artifact_dir: impl Into<PathBuf>, out_dir: impl Into<PathBuf>) -> Ctx {
        Ctx {
            artifact_dir: artifact_dir.into(),
            out_dir: out_dir.into(),
            model_name: "small".into(),
            model: None,
            profiles: HashMap::new(),
            converted: HashMap::new(),
            runtime: None,
            seed: 0xC0DE,
        }
    }

    /// The pretrained dense checkpoint (artifacts/small.cmw).
    pub fn model(&mut self) -> Result<&ModelWeights> {
        if self.model.is_none() {
            let path = self.artifact_dir.join(format!("{}.cmw", self.model_name));
            let m = ModelWeights::load(&path)
                .with_context(|| format!("load {} (run `make artifacts`)", path.display()))?;
            self.model = Some(m);
        }
        Ok(self.model.as_ref().unwrap())
    }

    pub fn runtime(&mut self) -> Result<std::sync::Arc<crate::runtime::XlaRuntime>> {
        if self.runtime.is_none() {
            self.runtime =
                Some(std::sync::Arc::new(crate::runtime::XlaRuntime::load(&self.artifact_dir)?));
        }
        Ok(self.runtime.as_ref().unwrap().clone())
    }

    /// Calibration token stream of `n` examples × CALIB_SEQ from a domain.
    pub fn calib_tokens(&self, domain: Domain, n: usize) -> Vec<usize> {
        let text = gen_corpus(&CorpusSpec {
            domain,
            bytes: n * CALIB_SEQ + 64,
            seed: self.seed ^ 0xCA11,
        });
        let mut toks = encode(&text);
        toks.truncate(n * CALIB_SEQ);
        toks
    }

    /// Held-out evaluation tokens (different seed from calibration).
    pub fn eval_tokens(&self, domain: Domain, tokens: usize) -> Vec<usize> {
        let text = gen_corpus(&CorpusSpec {
            domain,
            bytes: tokens + 64,
            seed: self.seed ^ 0xE7A1,
        });
        let mut toks = encode(&text);
        toks.truncate(tokens);
        toks
    }

    /// Per-layer activation profiles on a calibration set.
    pub fn profiles(
        &mut self,
        domain: Domain,
        n_examples: usize,
        k_a: usize,
    ) -> Result<Vec<ActivationProfile>> {
        let key = (domain.name().to_string(), n_examples, k_a);
        if !self.profiles.contains_key(&key) {
            let calib = self.calib_tokens(domain, n_examples);
            let model = self.model()?.clone();
            let p = profile_dense_model(&model, &calib, CALIB_SEQ, k_a);
            self.profiles.insert(key.clone(), p);
        }
        Ok(self.profiles[&key].clone())
    }

    /// CMoE conversion of the checkpoint (cached by spec string).
    pub fn convert(&mut self, spec: &MoeSpec) -> Result<ModelWeights> {
        let key = format!("cmoe:{spec}");
        if !self.converted.contains_key(&key) {
            let profiles = self.profiles(Domain::Markov, CALIB_EXAMPLES, KA)?;
            let model = self.model()?.clone();
            let ConvertedModel { model: m, .. } =
                convert_model(&model, &profiles, spec, &ConvertOptions::default())?;
            self.converted.insert(key.clone(), m);
        }
        Ok(self.converted[&key].clone())
    }

    /// CMoE conversion + gate fine-tuning on `samples` calibration rows.
    pub fn convert_finetuned(&mut self, spec: &MoeSpec, samples: usize) -> Result<ModelWeights> {
        let key = format!("cmoe-ft{samples}:{spec}");
        if !self.converted.contains_key(&key) {
            let mut m = self.convert(spec)?;
            let calib = self.calib_tokens(Domain::Markov, CALIB_EXAMPLES);
            let dense = self.model()?.clone();
            finetune_model(&mut m, &dense, &calib, samples)?;
            self.converted.insert(key.clone(), m);
        }
        Ok(self.converted[&key].clone())
    }

    /// The evaluation suites (Table 1's five-task analog).
    pub fn suites(&self) -> Vec<TaskSuite> {
        vec![
            TaskSuite {
                name: "Knowledge".into(),
                tasks: gen_choice_tasks(TaskFamily::Knowledge, 80, self.seed ^ 1),
            },
            TaskSuite {
                name: "Arith".into(),
                tasks: gen_choice_tasks(TaskFamily::Arith, 80, self.seed ^ 2),
            },
            TaskSuite {
                name: "Pattern".into(),
                tasks: gen_choice_tasks(TaskFamily::Pattern, 80, self.seed ^ 3),
            },
        ]
    }

    /// Save a results table as JSON.
    pub fn save(&self, exp: &str, tables: &[crate::util::table::Table]) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let arr = Json::Arr(tables.iter().map(|t| t.to_json()).collect());
        std::fs::write(self.out_dir.join(format!("{exp}.json")), arr.pretty())?;
        Ok(())
    }
}

/// Fine-tune every MoE layer's gates on `samples` token rows drawn from
/// the calibration stream (the paper's 2k-sample budget analog).
pub fn finetune_model(
    moe_model: &mut ModelWeights,
    dense_model: &ModelWeights,
    calib: &[usize],
    samples: usize,
) -> Result<()> {
    let fwd = DenseForward::new(dense_model);
    let take = samples.min(calib.len());
    let inputs = fwd.capture_ffn_inputs(&calib[..take.min(CALIB_SEQ)]);
    // gather more chunks if needed
    let mut per_layer: Vec<crate::tensor::Tensor> = inputs;
    let mut consumed = take.min(CALIB_SEQ);
    while consumed < take {
        let chunk = &calib[consumed..(consumed + CALIB_SEQ).min(take)];
        if chunk.len() < 2 {
            break;
        }
        let more = fwd.capture_ffn_inputs(chunk);
        for (acc, m) in per_layer.iter_mut().zip(more) {
            let mut data = std::mem::take(&mut acc.data);
            data.extend_from_slice(&m.data);
            let rows = acc.shape[0] + m.shape[0];
            *acc = crate::tensor::Tensor::from_vec(data, &[rows, m.shape[1]]);
        }
        consumed += CALIB_SEQ;
    }
    let cfg = crate::moe::FinetuneConfig::default();
    for (l, layer) in moe_model.layers.iter_mut().enumerate() {
        if let LayerFfn::Moe(moe) = &mut layer.ffn {
            crate::moe::finetune_gates(moe, &per_layer[l], &cfg);
        }
    }
    Ok(())
}

/// Convert the checkpoint with a per-layer baseline closure (shared by
/// the Table 1/5 runners).
pub fn convert_with_baseline(
    model: &ModelWeights,
    profiles: &[ActivationProfile],
    calib: &[usize],
    f: &dyn Fn(usize, &crate::model::FfnWeights, &crate::tensor::Tensor, &ActivationProfile) -> MoeLayerWeights,
) -> ModelWeights {
    let fwd = DenseForward::new(model);
    let inputs = fwd.capture_ffn_inputs(&calib[..CALIB_SEQ.min(calib.len())]);
    let mut out = model.clone();
    for (l, layer) in out.layers.iter_mut().enumerate() {
        let ffn = match &layer.ffn {
            LayerFfn::Dense(f) => f.clone(),
            LayerFfn::Moe(_) => continue,
        };
        layer.ffn = LayerFfn::Moe(f(l, &ffn, &inputs[l], &profiles[l]));
    }
    out
}

/// Structured-pruning baseline applied model-wide.
pub fn pruned_model(
    model: &ModelWeights,
    profiles: &[ActivationProfile],
    drop: f64,
) -> ModelWeights {
    baselines::pruning::prune_model(model, profiles, drop)
}
