//! Shared experiment context: the pretrained checkpoint, calibration
//! profiles, converted-model cache, task suites and corpora — so each
//! experiment runner stays small and the expensive pieces are computed
//! once.
//!
//! Conversions run through the [`crate::pipeline`] method registry:
//! [`Ctx::convert_method`] caches any `(method, spec, finetune)` cell,
//! with the profiling pass shared across the whole sweep via
//! [`Ctx::profiles`] + [`crate::pipeline::Pipeline::with_profiles`].

use crate::data::calibration::{CalibrationSpec, DEFAULT_KA, DEFAULT_SEQ};
use crate::data::corpus::Domain;
use crate::data::tasks_gen::{gen_choice_tasks, TaskFamily};
use crate::eval::tasks::TaskSuite;
use crate::model::{ModelWeights, MoeSpec};
use crate::pipeline::Pipeline;
use crate::profiling::ActivationProfile;
use crate::util::json::Json;
use anyhow::{Context as _, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Default calibration setup, mirroring the paper's §5.1 (8 examples,
/// K_a = 10; our sequences are 256 tokens at `small`'s max_seq).
pub const CALIB_EXAMPLES: usize = crate::data::calibration::DEFAULT_EXAMPLES;
pub const CALIB_SEQ: usize = DEFAULT_SEQ;
pub const KA: usize = DEFAULT_KA;

/// Gate fine-tuning against the dense teacher — the pipeline's
/// finetune stage, re-exported for experiment runners that fine-tune
/// models built outside a pipeline run.
pub use crate::pipeline::finetune_model;

/// Experiment context.
pub struct Ctx {
    pub artifact_dir: PathBuf,
    pub out_dir: PathBuf,
    pub model_name: String,
    model: Option<ModelWeights>,
    profiles: HashMap<(String, usize, usize), Vec<ActivationProfile>>, // (domain, n, ka)
    converted: HashMap<String, ModelWeights>,
    runtime: Option<std::sync::Arc<crate::runtime::XlaRuntime>>,
    pub seed: u64,
}

impl Ctx {
    pub fn new(artifact_dir: impl Into<PathBuf>, out_dir: impl Into<PathBuf>) -> Ctx {
        Ctx {
            artifact_dir: artifact_dir.into(),
            out_dir: out_dir.into(),
            model_name: "small".into(),
            model: None,
            profiles: HashMap::new(),
            converted: HashMap::new(),
            runtime: None,
            seed: 0xC0DE,
        }
    }

    /// The pretrained dense checkpoint (artifacts/small.cmw).
    pub fn model(&mut self) -> Result<&ModelWeights> {
        if self.model.is_none() {
            let path = self.artifact_dir.join(format!("{}.cmw", self.model_name));
            let m = ModelWeights::load(&path)
                .with_context(|| format!("load {} (run `make artifacts`)", path.display()))?;
            self.model = Some(m);
        }
        Ok(self.model.as_ref().unwrap())
    }

    pub fn runtime(&mut self) -> Result<std::sync::Arc<crate::runtime::XlaRuntime>> {
        if self.runtime.is_none() {
            self.runtime =
                Some(std::sync::Arc::new(crate::runtime::XlaRuntime::load(&self.artifact_dir)?));
        }
        Ok(self.runtime.as_ref().unwrap().clone())
    }

    /// The calibration setup every experiment shares (seeded by
    /// `self.seed`, so streams are reproducible across runners).
    pub fn calib_spec(&self, domain: Domain, n_examples: usize, k_a: usize) -> CalibrationSpec {
        CalibrationSpec { domain, examples: n_examples, seq: CALIB_SEQ, k_a, seed: self.seed }
    }

    /// Calibration token stream of `n` examples × CALIB_SEQ from a domain.
    pub fn calib_tokens(&self, domain: Domain, n: usize) -> Vec<usize> {
        self.calib_spec(domain, n, KA).calib_tokens()
    }

    /// Held-out evaluation tokens (different seed from calibration).
    pub fn eval_tokens(&self, domain: Domain, tokens: usize) -> Vec<usize> {
        self.calib_spec(domain, CALIB_EXAMPLES, KA).eval_tokens(tokens)
    }

    /// Per-layer activation profiles on a calibration set (cached).
    pub fn profiles(
        &mut self,
        domain: Domain,
        n_examples: usize,
        k_a: usize,
    ) -> Result<Vec<ActivationProfile>> {
        let key = (domain.name().to_string(), n_examples, k_a);
        if !self.profiles.contains_key(&key) {
            let spec = self.calib_spec(domain, n_examples, k_a);
            let model = self.model()?.clone();
            self.profiles.insert(key.clone(), spec.profiles(&model));
        }
        Ok(self.profiles[&key].clone())
    }

    /// Convert the checkpoint with any registered method (cached by
    /// method × spec × fine-tune budget). The per-domain profiling
    /// passes are computed once and shared across every method in the
    /// sweep via the pipeline's profile overrides.
    pub fn convert_method(
        &mut self,
        method: &str,
        spec: &MoeSpec,
        finetune_samples: usize,
    ) -> Result<ModelWeights> {
        let key = format!("{method}:{spec}:ft{finetune_samples}");
        if !self.converted.contains_key(&key) {
            let method_entry = crate::pipeline::registry::get(method)?;
            let needs_aux = method_entry.needs_aux_domain;
            let profiles = self.profiles(Domain::Markov, CALIB_EXAMPLES, KA)?;
            let model = self.model()?.clone();
            let mut pipe = Pipeline::from_method(method_entry)
                .spec(*spec)
                .calib(self.calib_spec(Domain::Markov, CALIB_EXAMPLES, KA))
                .with_profiles(profiles)
                .finetune(finetune_samples);
            if needs_aux {
                // the pipeline's aux domain for Markov is Arith — reuse
                // the cached pass instead of re-profiling per method
                pipe = pipe.with_aux_profiles(vec![self.profiles(
                    Domain::Arith,
                    CALIB_EXAMPLES,
                    KA,
                )?]);
            }
            let run = pipe
                .run(&model)
                .with_context(|| format!("convert via method '{method}'"))?;
            self.converted.insert(key.clone(), run.model);
        }
        Ok(self.converted[&key].clone())
    }

    /// CMoE conversion of the checkpoint (training-free).
    pub fn convert(&mut self, spec: &MoeSpec) -> Result<ModelWeights> {
        self.convert_method("cmoe", spec, 0)
    }

    /// CMoE conversion + gate fine-tuning on `samples` calibration rows.
    pub fn convert_finetuned(&mut self, spec: &MoeSpec, samples: usize) -> Result<ModelWeights> {
        self.convert_method("cmoe", spec, samples)
    }

    /// The evaluation suites (Table 1's five-task analog).
    pub fn suites(&self) -> Vec<TaskSuite> {
        vec![
            TaskSuite {
                name: "Knowledge".into(),
                tasks: gen_choice_tasks(TaskFamily::Knowledge, 80, self.seed ^ 1),
            },
            TaskSuite {
                name: "Arith".into(),
                tasks: gen_choice_tasks(TaskFamily::Arith, 80, self.seed ^ 2),
            },
            TaskSuite {
                name: "Pattern".into(),
                tasks: gen_choice_tasks(TaskFamily::Pattern, 80, self.seed ^ 3),
            },
        ]
    }

    /// Save a results table as JSON.
    pub fn save(&self, exp: &str, tables: &[crate::util::table::Table]) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let arr = Json::Arr(tables.iter().map(|t| t.to_json()).collect());
        std::fs::write(self.out_dir.join(format!("{exp}.json")), arr.pretty())?;
        Ok(())
    }
}

/// Structured-pruning baseline applied model-wide.
pub fn pruned_model(
    model: &ModelWeights,
    profiles: &[ActivationProfile],
    drop: f64,
) -> ModelWeights {
    crate::baselines::pruning::prune_model(model, profiles, drop)
}
