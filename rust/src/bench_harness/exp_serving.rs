//! Serving experiments: Table 9 (speedup across expert configurations,
//! context lengths, and memory- vs compute-bound regimes) and Figure 5
//! (load-balance adaptation), all measured through the real engine +
//! PJRT artifacts.

use crate::bench_harness::common::Ctx;
use crate::model::{ModelWeights, MoeSpec};
use crate::serving::{Engine, EngineConfig, ExecMode, GenParams, Request};
use crate::util::table::{f, speedup, Table};
use anyhow::Result;
use std::sync::Arc;

/// Run a decode-throughput measurement: returns tok/s.
fn measure_tps(
    rt: Arc<crate::runtime::XlaRuntime>,
    model: ModelWeights,
    cfg: EngineConfig,
    batch: usize,
    prompt_len: usize,
    new_tokens: usize,
) -> Result<f64> {
    let engine = Engine::new(rt, model, cfg)?;
    let reqs: Vec<Request> = (0..batch)
        .map(|i| {
            let prompt: Vec<usize> = (0..prompt_len).map(|j| (i * 7 + j * 13) % 250).collect();
            Request::new(
                i as u64,
                prompt,
                GenParams { max_new_tokens: new_tokens, temperature: 0.0, seed: i as u64, stop_token: None },
            )
        })
        .collect();
    // warmup wave (compilation)
    let warm: Vec<Request> = reqs.iter().take(batch).cloned().map(|mut r| {
        r.params.max_new_tokens = 2;
        r
    }).collect();
    engine.run_queue(warm)?;
    engine.metrics.lock().unwrap().waves.clear();
    engine.run_queue(reqs)?;
    let m = engine.metrics.lock().unwrap();
    Ok(m.decode_tps())
}

fn engine_cfg(
    model_name: &str,
    kv_len: usize,
    batch: usize,
    mode: ExecMode,
    spec: Option<MoeSpec>,
) -> EngineConfig {
    let mut cfg = match mode {
        ExecMode::Dense => EngineConfig::dense(model_name, kv_len),
        m => EngineConfig::moe(model_name, kv_len, spec.unwrap(), m),
    };
    cfg.batcher.buckets = vec![batch];
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    cfg
}

/// Shared helper (also used by Table 7): dense-vs-ours decode tok/s.
pub fn decode_throughput(
    ctx: &mut Ctx,
    dense: &ModelWeights,
    ours: &ModelWeights,
    batch: usize,
    kv_len: usize,
) -> Result<(f64, f64)> {
    let rt = ctx.runtime()?;
    let name = ctx.model_name.clone();
    let new_tokens = kv_len / 2 - 2;
    let dense_tps = measure_tps(
        rt.clone(),
        dense.clone(),
        engine_cfg(&name, kv_len, batch, ExecMode::Dense, None),
        batch,
        16,
        new_tokens,
    )?;
    let spec = match &ours.layers[0].ffn {
        crate::model::LayerFfn::Moe(m) => m.spec,
        _ => anyhow::bail!("ours must be converted"),
    };
    let ours_tps = measure_tps(
        rt,
        ours.clone(),
        engine_cfg(&name, kv_len, batch, ExecMode::MoeOrchestrated, Some(spec)),
        batch,
        16,
        new_tokens,
    )?;
    Ok((dense_tps, ours_tps))
}

/// Table 9: inference speedup across SxAyEz configs × context length ×
/// batch regime. Short/long context = KV 64 / 256; memory-bound = b1,
/// compute-bound = b32 (the paper's BS>400 analog on this testbed).
pub fn table9(ctx: &mut Ctx) -> Result<Table> {
    let rt = ctx.runtime()?;
    let name = ctx.model_name.clone();
    let dense = ctx.model()?.clone();
    let mut t = Table::new(
        "Table 9 — decode speedup vs dense (small; orchestrated MoE)",
        &["Config", "Mem-bound b1 ctx64", "Mem-bound b1 ctx256", "Comp-bound b32 ctx64", "Comp-bound b32 ctx256"],
    );
    for spec_s in ["S1A5E8", "S3A3E8", "S2A4E8", "S4A8E16", "S6A6E16", "S3A9E16"] {
        let spec: MoeSpec = spec_s.parse()?;
        let ours = ctx.convert_finetuned(&spec, 2048)?;
        let mut cells = vec![spec_s.to_string()];
        for (batch, kv_len) in [(1usize, 64usize), (1, 256), (32, 64), (32, 256)] {
            let new_tokens = (kv_len / 2 - 2).min(48);
            let d_tps = measure_tps(
                rt.clone(),
                dense.clone(),
                engine_cfg(&name, kv_len, batch, ExecMode::Dense, None),
                batch,
                16,
                new_tokens,
            )?;
            // orchestrated needs prefill_moe which is compiled only for
            // S3A3E8/S1A5E8; fall back to monolithic prefill spec? For
            // S2A4E8 we approximate prefill with the S3A3E8 artifact
            // being absent → run MoeOrchestrated only when compiled.
            let have_prefill = rt.has_artifact(&format!(
                "prefill_moe_{name}_{spec_s}_b{batch}_s16_t{kv_len}"
            ));
            let o_tps = if have_prefill {
                measure_tps(
                    rt.clone(),
                    ours.clone(),
                    engine_cfg(&name, kv_len, batch, ExecMode::MoeOrchestrated, Some(spec)),
                    batch,
                    16,
                    new_tokens,
                )?
            } else {
                f64::NAN
            };
            if o_tps.is_nan() {
                cells.push("n/a".into());
            } else {
                cells.push(speedup(o_tps / d_tps));
            }
        }
        t.row(cells);
    }
    ctx.save("table9", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Figure 5: expert utilization before/after bias adaptation, measured
/// live in the orchestrated engine.
///
/// The balanced clustering already yields near-uniform routing on this
/// checkpoint, so (as a controlled "before" state mirroring the paper's
/// skewed final layer) we plant a +0.3 routing bias on expert 0 of
/// every layer; adaptation must drain it back toward uniform.
pub fn fig5(ctx: &mut Ctx) -> Result<Table> {
    let spec: MoeSpec = "S3A3E8".parse()?;
    let mut ours = ctx.convert(&spec)?;
    for layer in ours.layers.iter_mut() {
        if let crate::model::LayerFfn::Moe(m) = &mut layer.ffn {
            m.gate_bias[0] = 0.3;
        }
    }
    let rt = ctx.runtime()?;
    let name = ctx.model_name.clone();

    let run = |balance: bool| -> Result<Vec<f64>> {
        let mut cfg = engine_cfg(&name, 64, 8, ExecMode::MoeOrchestrated, Some(spec));
        cfg.balance = if balance {
            Some(crate::moe::BalanceConfig { gamma: 5e-3, interval: 1 })
        } else {
            None
        };
        let engine = Engine::new(rt.clone(), ours.clone(), cfg)?;
        // drive enough waves for adaptation to act
        for w in 0..6 {
            let reqs: Vec<Request> = (0..8)
                .map(|i| {
                    let prompt: Vec<usize> =
                        (0..16).map(|j| (w * 31 + i * 7 + j * 13) % 250).collect();
                    Request::new(
                        (w * 8 + i) as u64,
                        prompt,
                        GenParams { max_new_tokens: 16, ..Default::default() },
                    )
                })
                .collect();
            engine.run_queue(reqs)?;
        }
        // measure final-layer utilization spread via a probe wave
        let biases = engine.current_biases();
        let last = biases.last().unwrap().clone();
        Ok(last.iter().map(|&b| b as f64).collect())
    };

    let without = run(false)?;
    let with = run(true)?;

    // measure the utilization each bias vector induces on a probe batch
    // (rust-side routing — identical logic to the engine's)
    let calib = ctx.calib_tokens(crate::data::corpus::Domain::Markov, 4);
    let dense = ctx.model()?.clone();
    let inputs = crate::eval::forward::DenseForward::new(&dense)
        .capture_ffn_inputs(&calib[..256]);
    let last_l = dense.config.n_layers - 1;
    let crate::model::LayerFfn::Moe(moe0) = &ours.layers[last_l].ffn else {
        anyhow::bail!("expected MoE layer");
    };
    let utilization = |biases: &[f64]| -> Vec<f64> {
        let mut m = moe0.clone();
        for (b, &v) in m.gate_bias.iter_mut().zip(biases) {
            *b = v as f32;
        }
        let (_, stats) = crate::moe::moe_ffn_forward(&m, &inputs[last_l]);
        stats.utilization()
    };
    let u_before = utilization(&without);
    let u_after = utilization(&with);

    let mut t = Table::new(
        "Figure 5 — load balancing: final-layer expert utilization (uniform = 1/N_r = 0.2)",
        &["Expert", "util (no adaptation)", "util (γ=5e-3)", "bias (adapted)"],
    );
    for e in 0..without.len() {
        t.row(vec![format!("{e}"), f(u_before[e], 3), f(u_after[e], 3), f(with[e], 4)]);
    }
    let spread = |u: &[f64]| {
        u.iter().cloned().fold(0.0, f64::max) - u.iter().cloned().fold(1.0, f64::min)
    };
    t.row(vec![
        "max-min".into(),
        f(spread(&u_before), 3),
        f(spread(&u_after), 3),
        "-".into(),
    ]);
    ctx.save("fig5", std::slice::from_ref(&t))?;
    Ok(t)
}
