//! Serving experiments: Table 9 (speedup across expert configurations,
//! context lengths, and memory- vs compute-bound regimes), Figure 5
//! (load-balance adaptation) — both measured through the real engine +
//! PJRT artifacts — and two artifact-free sweeps that run on a fresh
//! clone:
//!
//! * the **grouped-dispatch sweep** ([`dispatch_sweep`]): dense vs
//!   per-token vs grouped expert execution across batch size and
//!   activation ratio — CMoE's FLOP savings as decode throughput;
//! * the **scheduling sweep** ([`serving_sweep`]): continuous
//!   in-flight batching vs run-to-completion waves on Poisson
//!   open-loop arrival traces with mixed prompt/generation lengths,
//!   measured in decode-step throughput, batch-row occupancy, and
//!   step-metered TTFT — the head-of-line-blocking evidence behind
//!   the continuous scheduler. Exported to the repo-root
//!   `BENCH_serving.json` for the cross-PR perf trajectory.

use crate::bench_harness::common::Ctx;
use crate::converter::{convert_ffn, ConvertOptions};
use crate::model::{FfnWeights, ModelWeights, MoeSpec};
use crate::moe::{route_tokens, GroupedRouting};
use crate::profiling::ActivationProfile;
use crate::serving::{
    per_token_reference, stub_reference, BatcherConfig, ContinuousSession, DispatchArena,
    Engine, EngineConfig, ExecMode, GenParams, GroupedDispatcher, Request, StepForward,
    StubForward,
};
use crate::tensor::{self, Tensor};
use crate::util::stats::percentile;
use crate::util::table::{f, speedup, Table};
use crate::util::timer::measure;
use crate::util::Rng;
use anyhow::{Context as _, Result};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

/// The grouped-dispatch sweep as a bench-harness experiment
/// (`cmoe bench --exp dispatch`). Artifact-free: runs on a synthetic
/// converted layer, so it works on a fresh clone.
pub fn dispatch_sweep(ctx: &mut Ctx) -> Result<Table> {
    let t = dispatch_sweep_table(ctx.seed, 5, Duration::from_millis(60))?;
    ctx.save("dispatch", std::slice::from_ref(&t))?;
    // Perf trajectory across PRs: a second copy at the repo root with a
    // stable name, so successive PRs can diff decode throughput without
    // digging through results/ directories. Outside a CMoE checkout it
    // falls back to the results directory rather than guessing.
    let root = crate::util::repo_root().unwrap_or_else(|| ctx.out_dir.clone());
    let path = root.join("BENCH_dispatch.json");
    std::fs::write(&path, t.to_json().pretty())
        .with_context(|| format!("write {}", path.display()))?;
    eprintln!("dispatch sweep exported to {}", path.display());
    Ok(t)
}

/// Ctx-free sweep core (also driven by `cargo bench --bench
/// serving_bench`, which has no artifact directory).
///
/// One dense FFN (`d = 128`, `d_ff = 1024`) is converted at three
/// activation ratios (25/50/75% — `SxAxE8` with x = 1, 2, 3); for each
/// ratio × batch the routed experts execute through (a) the per-token
/// baseline (one tiny SwiGLU per assignment) and (b) the grouped
/// dispatcher, against (c) the unconverted dense FFN. The shared expert
/// is identical work on both MoE paths and is omitted so the delta is
/// purely dispatch. The "arena growths" column counts arena
/// reallocations *during the measured steady state* — it must read 0.
pub fn dispatch_sweep_table(seed: u64, min_iters: usize, min_time: Duration) -> Result<Table> {
    let mut rng = Rng::new(seed ^ 0xD15);
    let d = 128usize;
    let d_ff = 1024usize;
    let ffn = FfnWeights {
        w_gate: Tensor::randn(&mut rng, &[d, d_ff], 0.4),
        w_up: Tensor::randn(&mut rng, &[d, d_ff], 0.4),
        w_down: Tensor::randn(&mut rng, &[d_ff, d], 0.4),
    };
    let xc = Tensor::randn(&mut rng, &[256, d], 1.0);
    let h = tensor::swiglu_hidden(&xc, &ffn.w_gate, &ffn.w_up);
    let prof = ActivationProfile::from_hidden(&h, 10);
    let mut t = Table::new(
        "Grouped dispatch sweep — routed-FFN decode tok/s: dense vs per-token vs grouped",
        &[
            "Spec",
            "Active",
            "Batch",
            "dense tok/s",
            "per-token tok/s",
            "grouped tok/s",
            "grouped/per-token",
            "grouped/dense",
            "arena growths",
        ],
    );
    for spec_s in ["S1A1E8", "S2A2E8", "S3A3E8"] {
        let spec: MoeSpec = spec_s.parse()?;
        let mut moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default())?;
        moe.compensation = None;
        let n_r = spec.routed();
        let m = moe.experts[0].hidden_dim();
        let disp = GroupedDispatcher::new(d, m);
        let mut arena = DispatchArena::new();
        let mut routing = GroupedRouting::new(n_r);
        for &batch in &[1usize, 8, 32, 128] {
            let xn = Tensor::randn(&mut rng, &[batch, d], 1.0);
            let decisions = route_tokens(&moe, &xn);
            let mut out = Tensor::zeros(&[batch, d]);

            // (c) dense baseline: the unconverted FFN on the same wave
            let dense_s = measure(
                || {
                    let y = tensor::swiglu_ffn(&xn, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
                    std::hint::black_box(&y);
                },
                min_iters,
                min_time,
            );

            // (a) per-token baseline
            let pt_s = measure(
                || {
                    out.data.fill(0.0);
                    per_token_reference(&xn, &decisions, &moe.experts, &mut out);
                    std::hint::black_box(&out);
                },
                min_iters,
                min_time,
            );

            // (b) grouped: warm the arena once, then measure steady state
            routing.rebuild(n_r, &decisions);
            out.data.fill(0.0);
            disp.forward(&xn, &routing, &moe.experts, &mut arena, &mut out);
            let growths_before = arena.grow_events();
            let g_s = measure(
                || {
                    routing.rebuild(n_r, &decisions);
                    out.data.fill(0.0);
                    disp.forward(&xn, &routing, &moe.experts, &mut arena, &mut out);
                    std::hint::black_box(&out);
                },
                min_iters,
                min_time,
            );
            let growths = arena.grow_events() - growths_before;

            let tps = |samples: &[Duration]| -> f64 {
                let ns: Vec<f32> = samples.iter().map(|d| d.as_secs_f32() * 1e9).collect();
                let mean = crate::util::stats::mean(&ns) as f64;
                if mean <= 0.0 {
                    0.0
                } else {
                    batch as f64 / (mean / 1e9)
                }
            };
            let (dt, pt, gt) = (tps(&dense_s), tps(&pt_s), tps(&g_s));
            t.row(vec![
                spec_s.to_string(),
                format!("{:.0}%", spec.active_fraction() * 100.0),
                batch.to_string(),
                f(dt, 0),
                f(pt, 0),
                f(gt, 0),
                speedup(gt / pt),
                speedup(gt / dt),
                growths.to_string(),
            ]);
        }
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Scheduling sweep: continuous in-flight batching vs run-to-completion
// ---------------------------------------------------------------------------

const SWEEP_VOCAB: usize = 23;
const SWEEP_KV_CAP: usize = 128;
const SWEEP_BUCKETS: &[usize] = &[1, 8, 32];

/// Knuth Poisson sampler (λ small, so the naive product is fine).
pub(super) fn poisson(rng: &mut Rng, lambda: f64) -> usize {
    let l = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0f64;
    loop {
        p *= rng.f32() as f64;
        if p <= l {
            return k;
        }
        k += 1;
    }
}

/// Open-loop Poisson trace: `(arrival_step, request)` with mixed
/// prompt lengths (1–16), generation budgets (2–41) and occasional
/// stop tokens.
fn gen_trace(rng: &mut Rng, lambda: f64, n_req: usize) -> Vec<(u64, Request)> {
    let mut out = Vec::with_capacity(n_req);
    let mut step = 0u64;
    while out.len() < n_req {
        for _ in 0..poisson(rng, lambda) {
            if out.len() >= n_req {
                break;
            }
            let id = out.len() as u64;
            let prompt: Vec<usize> =
                (0..1 + rng.below(16)).map(|_| rng.below(SWEEP_VOCAB)).collect();
            let params = GenParams {
                max_new_tokens: 2 + rng.below(40),
                temperature: 0.0,
                seed: id ^ 0x5EED,
                stop_token: if rng.f32() < 0.2 { Some(rng.below(SWEEP_VOCAB)) } else { None },
            };
            out.push((step, Request::new(id, prompt, params)));
        }
        step += 1;
    }
    out
}

/// Step-metered outcome of one scheduling policy over one trace.
struct SimOutcome {
    requests: usize,
    tokens: usize,
    decode_steps: u64,
    /// GEMM rows executed over all decode steps (bucket-padded).
    row_steps: u64,
    /// Rows that carried a live request.
    live_rows: u64,
    ttft_steps: Vec<f32>,
    queue_steps: Vec<f32>,
    /// Per-request mean decode interval in steps
    /// (`decode_span_steps / (tokens - 1)`, multi-token requests only).
    /// 1.0 means "a token every step" — the no-stall property.
    tpot_steps: Vec<f32>,
    /// Requests that retired without emitting a first token (shed,
    /// failed). Excluded from the TTFT percentiles above — a 0ms TTFT
    /// for a request that never produced a token is not a latency.
    no_first_token: usize,
}

impl SimOutcome {
    fn tok_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.tokens as f64 / self.decode_steps as f64
    }

    fn occupancy(&self) -> f64 {
        if self.row_steps == 0 {
            return 0.0;
        }
        self.live_rows as f64 / self.row_steps as f64
    }

    fn row(&self, sched: &str, lambda: f64) -> Vec<String> {
        vec![
            sched.into(),
            format!("{lambda:.1}"),
            self.requests.to_string(),
            self.tokens.to_string(),
            self.decode_steps.to_string(),
            f(self.tok_per_step(), 2),
            format!("{:.0}%", self.occupancy() * 100.0),
            f(percentile(&self.ttft_steps, 50.0) as f64, 1),
            f(percentile(&self.ttft_steps, 99.0) as f64, 1),
            f(percentile(&self.queue_steps, 50.0) as f64, 1),
            f(percentile(&self.tpot_steps, 99.0) as f64, 2),
        ]
    }
}

/// Replay a trace through the real [`ContinuousSession`] driving the
/// deterministic stub model, at the given per-step prefill chunk
/// budget (`0` = monolithic). TTFT and TPOT come from the scheduler's
/// own step-denominated stamps ([`crate::serving::RequestResult`]'s
/// `ttft_steps` / `decode_span_steps`) rather than being reconstructed
/// from queue delay — the reconstruction was wrong for multi-chunk
/// prefills and reported a fictional 0-step TTFT for requests that
/// never emitted a token (those are now counted, not averaged in).
fn continuous_sim(trace: &[(u64, Request)], chunk: usize) -> Result<SimOutcome> {
    let pool = *SWEEP_BUCKETS.last().unwrap();
    let mut sess = ContinuousSession::new(
        BatcherConfig {
            buckets: SWEEP_BUCKETS.to_vec(),
            max_wait: Duration::ZERO,
            prefill_chunk_tokens: chunk,
            ..Default::default()
        },
        StubForward::new(pool, SWEEP_VOCAB, SWEEP_KV_CAP),
    )?;
    let mut next = 0;
    let mut tokens = 0usize;
    let mut done = 0usize;
    let mut ttft_steps = Vec::new();
    let mut queue_steps = Vec::new();
    let mut tpot_steps = Vec::new();
    let mut no_first_token = 0usize;
    while next < trace.len() || !sess.is_idle() {
        while next < trace.len() && trace[next].0 <= sess.step_index() {
            sess.enqueue(trace[next].1.clone());
            next += 1;
        }
        for r in sess.step()? {
            tokens += r.tokens.len();
            done += 1;
            match r.ttft_steps {
                Some(s) => ttft_steps.push(s as f32),
                None => no_first_token += 1,
            }
            if r.tokens.len() > 1 {
                tpot_steps.push(r.decode_span_steps as f32 / (r.tokens.len() - 1) as f32);
            }
            queue_steps.push(r.queued_steps as f32);
        }
        anyhow::ensure!(sess.step_index() < 10_000_000, "sweep failed to converge");
    }
    let m = sess.metrics();
    Ok(SimOutcome {
        requests: done,
        tokens,
        decode_steps: m.decode_steps,
        row_steps: m.bucket_row_steps,
        live_rows: m.live_row_steps,
        ttft_steps,
        queue_steps,
        tpot_steps,
        no_first_token,
    })
}

/// Run-to-completion comparator on the same trace: waves form in
/// arrival order at the wave-bucket policy, decode until their longest
/// member finishes (retired members pad every step), and the next wave
/// waits for the whole previous one. Per-request token counts come
/// from [`stub_reference`] — by the token-identity guarantee they are
/// exactly what the wave engine would generate, so only the schedule
/// needs simulating (a wave of lengths `L` costs `max(L) - 1` decode
/// steps after its prefill step).
fn wave_sim(trace: &[(u64, Request)]) -> SimOutcome {
    let bucket_for = |n: usize| crate::serving::covering_bucket(SWEEP_BUCKETS, n);
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut next = 0;
    let mut t = 0u64;
    let mut out = SimOutcome {
        requests: 0,
        tokens: 0,
        decode_steps: 0,
        row_steps: 0,
        live_rows: 0,
        ttft_steps: Vec::new(),
        queue_steps: Vec::new(),
        tpot_steps: Vec::new(),
        no_first_token: 0,
    };
    loop {
        while next < trace.len() && trace[next].0 <= t {
            queue.push_back(next);
            next += 1;
        }
        if queue.is_empty() {
            if next >= trace.len() {
                break;
            }
            t = trace[next].0; // idle until the next arrival
            continue;
        }
        let bucket = bucket_for(queue.len());
        let take = queue.len().min(bucket);
        let members: Vec<usize> = queue.drain(..take).collect();
        let lens: Vec<usize> = members
            .iter()
            .map(|&i| stub_reference(&trace[i].1, SWEEP_VOCAB, SWEEP_KV_CAP).len())
            .collect();
        let max_len = *lens.iter().max().unwrap();
        for (&i, &len) in members.iter().zip(&lens) {
            out.requests += 1;
            out.tokens += len;
            out.live_rows += (len - 1) as u64;
            out.ttft_steps.push((t - trace[i].0) as f32 + 1.0);
            out.queue_steps.push((t - trace[i].0) as f32);
            if len > 1 {
                // a wave member decodes every step of its wave
                out.tpot_steps.push(1.0);
            }
        }
        out.decode_steps += (max_len - 1) as u64;
        out.row_steps += ((max_len - 1) * bucket) as u64;
        // the wave occupies prefill + decode steps; the next wave (and
        // every queued request) waits for all of it
        t += max_len as u64;
    }
    out
}

// ---------------------------------------------------------------------------
// Chunked-prefill sweep: long-prompt + decode mixed trace, token-time metered
// ---------------------------------------------------------------------------

/// Per-step prefill token budget of the chunked arm.
const CHUNK_SWEEP_BUDGET: usize = 32;
/// Token-time units per tick of the arrival process (`λ` below is
/// arrivals per tick). Coarse on purpose: arrivals land at scattered
/// offsets inside scheduler steps, so the boundary wait a monolithic
/// mega-step imposes on them is actually exercised.
const CHUNK_ARRIVAL_TICK: u64 = 64;

/// [`StepForward`] decorator that meters compute in **token units**:
/// each prefill call costs its suffix tokens, each decode call costs
/// its live rows. The chunked sweep uses the cumulative count as a
/// deterministic wall-clock model — a step lasts as long as the work
/// it computes — which is exactly the regime where monolithic prefill
/// hurts: one 96-token prompt makes one enormous step, and every
/// in-flight decode (and every arrival waiting for the step boundary)
/// pays for it. Step-count metering cannot see this; it prices that
/// step at 1.
struct CostMeter<F: StepForward> {
    inner: F,
    /// Cumulative compute, in tokens (prefill suffixes + decode rows).
    tokens: u64,
}

impl<F: StepForward> CostMeter<F> {
    fn new(inner: F) -> Self {
        CostMeter { inner, tokens: 0 }
    }
}

impl<F: StepForward> StepForward for CostMeter<F> {
    fn map_prefix(&mut self, slot: usize, prompt: &[usize]) -> Result<Option<usize>> {
        self.inner.map_prefix(slot, prompt)
    }

    fn prefill(
        &mut self,
        slots: &[usize],
        prompts: &[&[usize]],
        cached: &[usize],
    ) -> Result<Vec<crate::serving::PrefillOutcome>> {
        for (p, &c) in prompts.iter().zip(cached) {
            self.tokens += (p.len() - c) as u64;
        }
        self.inner.prefill(slots, prompts, cached)
    }

    fn decode(
        &mut self,
        slots: &[usize],
        tokens: &[i32],
        pos: &[usize],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        self.tokens += slots.len() as u64;
        self.inner.decode(slots, tokens, pos, bucket)
    }

    fn release(&mut self, slot: usize) {
        self.inner.release(slot);
    }

    fn park(&mut self, slot: usize) -> Option<crate::runtime::ParkedSlot> {
        self.inner.park(slot)
    }

    fn unpark(&mut self, slot: usize, parked: crate::runtime::ParkedSlot) {
        self.inner.unpark(slot, parked);
    }

    fn drop_parked(&mut self, parked: crate::runtime::ParkedSlot) {
        self.inner.drop_parked(parked);
    }

    fn kv_capacity(&self) -> usize {
        self.inner.kv_capacity()
    }

    fn set_slot_ratio(&mut self, slot: usize, ratio: f32) {
        self.inner.set_slot_ratio(slot, ratio);
    }

    fn page_metrics(&self) -> Option<crate::serving::PageMetrics> {
        self.inner.page_metrics()
    }
}

/// Long-prompt-plus-decode mixed trace in **token-time**: arrivals are
/// stamped in the same token units the [`CostMeter`] clock advances
/// in. A quarter of the requests carry a long prompt (64–96 tokens —
/// several chunk budgets); the rest are short prompts with a modest
/// decode, the live traffic a long prefill would freeze.
fn gen_long_trace(rng: &mut Rng, lambda: f64, n_req: usize) -> Vec<(u64, Request)> {
    let mut out = Vec::with_capacity(n_req);
    let mut tick = 0u64;
    while out.len() < n_req {
        for _ in 0..poisson(rng, lambda) {
            if out.len() >= n_req {
                break;
            }
            let id = out.len() as u64;
            let long = rng.f32() < 0.25;
            let plen = if long { 64 + rng.below(33) } else { 2 + rng.below(9) };
            let prompt: Vec<usize> = (0..plen).map(|_| rng.below(SWEEP_VOCAB)).collect();
            let params = GenParams {
                max_new_tokens: if long { 2 + rng.below(8) } else { 4 + rng.below(13) },
                temperature: 0.0,
                seed: id ^ 0xC41F,
                stop_token: None,
            };
            out.push((tick * CHUNK_ARRIVAL_TICK, Request::new(id, prompt, params)));
        }
        tick += 1;
    }
    out
}

/// One prefill policy's outcome over one token-time metered trace.
struct ChunkedOutcome {
    /// Per-request token streams, indexed by request id (the identity
    /// oracle between the two policies).
    tokens_by_id: Vec<Vec<usize>>,
    steps: u64,
    /// Total compute in token units — equal across policies by
    /// construction (same prefill tokens, same decode tokens), which
    /// [`chunked_sweep_table`] enforces.
    compute_tokens: u64,
    /// Per-request first-token latency in token-time.
    ttft_tok: Vec<f32>,
    /// Per-**gap** inter-token latency in token-time (every decode
    /// interval of every request) — the stall a monolithic prefill
    /// inflicts on live decodes lands here, in the tail.
    tpot_tok: Vec<f32>,
}

/// Replay a token-time trace at the given prefill chunk budget
/// (`0` = monolithic). The clock advances by each step's metered
/// compute; arrivals enqueue at the first step boundary at or after
/// their stamp — so a long monolithic prefill step delays every
/// arrival that lands inside it, which is the effect under test.
fn chunked_sim(trace: &[(u64, Request)], chunk: usize) -> Result<ChunkedOutcome> {
    let pool = *SWEEP_BUCKETS.last().unwrap();
    let mut sess = ContinuousSession::new(
        BatcherConfig {
            buckets: SWEEP_BUCKETS.to_vec(),
            max_wait: Duration::ZERO,
            prefill_chunk_tokens: chunk,
            ..Default::default()
        },
        CostMeter::new(StubForward::new(pool, SWEEP_VOCAB, SWEEP_KV_CAP)),
    )?;
    let mut next = 0;
    let mut t_tok = 0u64;
    // token-time at the end of each scheduler step, indexed by step
    let mut step_end: Vec<u64> = Vec::new();
    let mut enq_step = vec![0u64; trace.len()];
    let mut arrival = vec![0u64; trace.len()];
    for (t, r) in trace {
        arrival[r.id as usize] = *t;
    }
    let mut raw: Vec<(usize, Vec<usize>, Option<u64>, u64)> = Vec::new();
    while next < trace.len() || !sess.is_idle() {
        if sess.is_idle() && next < trace.len() && trace[next].0 > t_tok {
            t_tok = trace[next].0; // idle: jump to the next arrival
        }
        while next < trace.len() && trace[next].0 <= t_tok {
            enq_step[trace[next].1.id as usize] = sess.step_index();
            sess.enqueue(trace[next].1.clone());
            next += 1;
        }
        let before = sess.forward().tokens;
        for r in sess.step()? {
            raw.push((r.id as usize, r.tokens, r.ttft_steps, r.decode_span_steps));
        }
        // a zero-work step still ticks, or an idle tail would hang
        let cost = (sess.forward().tokens - before).max(1);
        t_tok += cost;
        step_end.push(t_tok);
        anyhow::ensure!(step_end.len() < 10_000_000, "chunked sweep failed to converge");
    }
    let mut out = ChunkedOutcome {
        tokens_by_id: vec![Vec::new(); trace.len()],
        steps: step_end.len() as u64,
        compute_tokens: sess.forward().tokens,
        ttft_tok: Vec::new(),
        tpot_tok: Vec::new(),
    };
    for (id, tokens, ttft_steps, span) in raw {
        if let Some(ts) = ttft_steps {
            // ttft_steps = first_token_step - enqueue_step + 1
            let ft = (enq_step[id] + ts - 1) as usize;
            out.ttft_tok.push((step_end[ft] - arrival[id]) as f32);
            // without preemption a live request decodes every step —
            // including the step its final prefill chunk lands in, so
            // tokens 1 and 2 share step `ft` and token k ≥ 2 lands at
            // step ft + k - 1: the decode intervals are the step
            // durations over [ft, ft + span), span = tokens - 2
            debug_assert_eq!(
                span as usize,
                tokens.len().saturating_sub(2),
                "decode span vs stream length"
            );
            for s in ft..ft + span as usize {
                out.tpot_tok.push((step_end[s + 1] - step_end[s]) as f32);
            }
        }
        out.tokens_by_id[id] = tokens;
    }
    Ok(out)
}

impl ChunkedOutcome {
    fn row(&self, prefill: &str, lambda: f64) -> Vec<String> {
        vec![
            prefill.into(),
            format!("{lambda:.1}"),
            self.tokens_by_id.len().to_string(),
            self.tokens_by_id.iter().map(Vec::len).sum::<usize>().to_string(),
            self.steps.to_string(),
            self.compute_tokens.to_string(),
            f(percentile(&self.ttft_tok, 50.0) as f64, 0),
            f(percentile(&self.ttft_tok, 99.0) as f64, 0),
            f(percentile(&self.tpot_tok, 50.0) as f64, 0),
            f(percentile(&self.tpot_tok, 99.0) as f64, 0),
        ]
    }
}

/// The chunked-prefill sweep core: one long-prompt-plus-decode trace
/// per arrival rate, replayed monolithic and chunked. Token identity
/// and total-compute equality between the two runs are invariants,
/// enforced here; what chunking is allowed to change — and what the
/// table shows — is where that compute sits. Chunking is a pure
/// reordering of equal work, so the honest result has two faces:
/// `tpot_p99` — the stall a monolithic prefill inflicts on every live
/// decode gap — collapses by roughly the mega-step/chunk ratio at
/// every load, while `ttft_p99` is a trade. At moderate load (λ = 2)
/// finer step boundaries let arrivals enqueue mid-prefill instead of
/// waiting out a monolithic mega-step, and the TTFT tail drops too;
/// under overload (λ = 3) the tail is queue-wait both ways and
/// chunking merely holds it within a few percent (the long prompt's
/// own first token moves *later* — the decode work it no longer
/// stalls is charged ahead of it). The unit test pins both faces.
pub fn chunked_sweep_table(seed: u64, n_req: usize) -> Result<Table> {
    let mut t = Table::new(
        "Chunked prefill sweep — long-prompt + decode mixed trace, monolithic vs \
         chunked prefill (stub; token-time metering: a step costs the prefill \
         tokens + decode rows it computes; chunk budget 32)",
        &[
            "Prefill",
            "λ/tick",
            "Requests",
            "Tokens",
            "Steps",
            "Compute tok",
            "ttft_p50 (tok)",
            "ttft_p99 (tok)",
            "tpot_p50 (tok)",
            "tpot_p99 (tok)",
        ],
    );
    for &lambda in &[2.0f64, 3.0] {
        let mut rng = Rng::new(seed ^ ((lambda * 8.0) as u64) ^ 0xC41F);
        let trace = gen_long_trace(&mut rng, lambda, n_req);
        let mono = chunked_sim(&trace, 0)?;
        let chunked = chunked_sim(&trace, CHUNK_SWEEP_BUDGET)?;
        anyhow::ensure!(
            mono.tokens_by_id == chunked.tokens_by_id,
            "chunked prefill changed a token stream at λ={lambda}"
        );
        anyhow::ensure!(
            mono.compute_tokens == chunked.compute_tokens,
            "chunking changed total compute at λ={lambda}: {} vs {}",
            mono.compute_tokens,
            chunked.compute_tokens
        );
        t.row(mono.row("monolithic", lambda));
        t.row(chunked.row(&format!("chunked {CHUNK_SWEEP_BUDGET}"), lambda));
    }
    Ok(t)
}

/// The scheduling sweep as a bench-harness experiment (`cmoe bench
/// --exp serving`). Artifact-free; exports a repo-root
/// `BENCH_serving.json` so successive PRs can diff serving throughput,
/// TTFT and occupancy without digging through results/ directories —
/// since the chunked-prefill PR with the chunked sweep attached under
/// the `"chunked"` key (`ttft_p99`/`tpot_p99` in token-time) — and,
/// since the paged-KV PR, also refreshes `BENCH_prefix.json` so one
/// `--exp serving` run keeps the whole serving trajectory current.
pub fn serving_sweep(ctx: &mut Ctx) -> Result<Table> {
    let t = serving_sweep_table(ctx.seed, 160)?;
    let chunked = chunked_sweep_table(ctx.seed, 128)?;
    ctx.save("serving", &[t.clone(), chunked.clone()])?;
    let root = crate::util::repo_root().unwrap_or_else(|| ctx.out_dir.clone());
    let path = root.join("BENCH_serving.json");
    let mut j = t.to_json();
    j.set("chunked", chunked.to_json());
    std::fs::write(&path, j.pretty())
        .with_context(|| format!("write {}", path.display()))?;
    eprintln!("serving sweep exported to {}", path.display());
    export_prefix_json(ctx)?;
    // the dynamic-activation operating points ride along so one
    // `--exp serving` run refreshes the whole serving trajectory
    super::exp_dynk::export_dynk_json(ctx)?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Prefix sweep: shared-system-prompt workload, KV page sharing on vs off
// ---------------------------------------------------------------------------

/// Tokens per KV page in the prefix sweep (system prompts span several
/// pages, so sharing has something to map).
const PREFIX_PAGE_LEN: usize = 8;
/// System-prompt length in tokens (3 pages at `PREFIX_PAGE_LEN`).
const PREFIX_SYS_LEN: usize = 24;
/// Distinct system prompts in the workload.
const PREFIX_N_SYS: usize = 3;

/// Shared-system-prompt open-loop trace: every request is one of
/// `PREFIX_N_SYS` fixed system prompts plus a short unique user
/// suffix — the ROADMAP's "millions of users with near-identical
/// preambles" workload in miniature.
fn gen_prefix_trace(rng: &mut Rng, lambda: f64, n_req: usize) -> Vec<(u64, Request)> {
    let sys: Vec<Vec<usize>> = (0..PREFIX_N_SYS)
        .map(|_| (0..PREFIX_SYS_LEN).map(|_| rng.below(SWEEP_VOCAB)).collect())
        .collect();
    let mut out = Vec::with_capacity(n_req);
    let mut step = 0u64;
    while out.len() < n_req {
        for _ in 0..poisson(rng, lambda) {
            if out.len() >= n_req {
                break;
            }
            let id = out.len() as u64;
            // suffixes stay below one page, so the cache only ever
            // holds the genuinely shared system pages
            let mut prompt = sys[rng.below(PREFIX_N_SYS)].clone();
            prompt.extend((0..2 + rng.below(6)).map(|_| rng.below(SWEEP_VOCAB)));
            let params = GenParams {
                max_new_tokens: 2 + rng.below(24),
                temperature: 0.0,
                seed: id ^ 0x9A6E,
                stop_token: if rng.f32() < 0.15 { Some(rng.below(SWEEP_VOCAB)) } else { None },
            };
            out.push((step, Request::new(id, prompt, params)));
        }
        step += 1;
    }
    out
}

/// One sharing policy's outcome over one trace.
struct PrefixOutcome {
    /// Per-request token streams, indexed by request id (the identity
    /// oracle between the two policies).
    tokens_by_id: Vec<Vec<usize>>,
    decode_steps: u64,
    generated: usize,
    prefill_tokens: u64,
    prefill_saved: u64,
    hit_rate: f64,
    high_water_pages: usize,
    cow_copies: u64,
    ttft_steps: Vec<f32>,
}

impl PrefixOutcome {
    fn tok_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.generated as f64 / self.decode_steps as f64
    }

    fn row(&self, sharing: &str, lambda: f64) -> Vec<String> {
        vec![
            sharing.into(),
            format!("{lambda:.1}"),
            self.tokens_by_id.len().to_string(),
            self.prefill_tokens.to_string(),
            self.prefill_saved.to_string(),
            format!("{:.0}%", self.hit_rate * 100.0),
            self.high_water_pages.to_string(),
            self.cow_copies.to_string(),
            f(self.tok_per_step(), 2),
            f(percentile(&self.ttft_steps, 50.0) as f64, 1),
        ]
    }
}

/// Replay a shared-prefix trace through the continuous session with KV
/// page sharing on or off (same paged pool either way — only the
/// prefix cache differs).
fn prefix_sim(trace: &[(u64, Request)], sharing: bool) -> Result<PrefixOutcome> {
    let pool = *SWEEP_BUCKETS.last().unwrap();
    let fwd = if sharing {
        StubForward::with_prefix_cache(pool, SWEEP_VOCAB, SWEEP_KV_CAP, PREFIX_PAGE_LEN)
    } else {
        StubForward::new(pool, SWEEP_VOCAB, SWEEP_KV_CAP)
    };
    let mut sess = ContinuousSession::new(
        BatcherConfig { buckets: SWEEP_BUCKETS.to_vec(), max_wait: Duration::ZERO, ..Default::default() },
        fwd,
    )?;
    let mut next = 0;
    let mut tokens_by_id: Vec<Vec<usize>> = vec![Vec::new(); trace.len()];
    let mut generated = 0usize;
    let mut ttft_steps = Vec::new();
    while next < trace.len() || !sess.is_idle() {
        while next < trace.len() && trace[next].0 <= sess.step_index() {
            sess.enqueue(trace[next].1.clone());
            next += 1;
        }
        for r in sess.step()? {
            generated += r.tokens.len();
            if let Some(s) = r.ttft_steps {
                ttft_steps.push(s as f32);
            }
            tokens_by_id[r.id as usize] = r.tokens;
        }
        anyhow::ensure!(sess.step_index() < 10_000_000, "prefix sweep failed to converge");
    }
    let m = sess.metrics();
    let pm = sess.forward().page_metrics().expect("stub owns a page pool");
    Ok(PrefixOutcome {
        decode_steps: m.decode_steps,
        generated,
        prefill_tokens: m.prefill_tokens,
        prefill_saved: m.prefill_tokens_saved,
        hit_rate: m.prefix_hit_rate(),
        high_water_pages: pm.high_water_pages,
        cow_copies: pm.cow_copies,
        ttft_steps,
        tokens_by_id,
    })
}

/// The prefix-sharing sweep core: one shared-system-prompt trace per
/// arrival rate, replayed with the prefix cache off and on. Token
/// identity between the two runs is an invariant, enforced here — the
/// sweep measures only what sharing is allowed to change: prefill
/// tokens, page occupancy, hit rate.
pub fn prefix_sweep_table(seed: u64, n_req: usize) -> Result<Table> {
    let mut t = Table::new(
        "Prefix sweep — shared-system-prompt workload, KV page sharing off vs on \
         (stub model; page_len 8, 3 system prompts × 24 tokens; buckets {1,8,32})",
        &[
            "Sharing",
            "λ/step",
            "Requests",
            "Prefill tok",
            "Reused tok",
            "Hit rate",
            "KV pages hw",
            "COW",
            "tok/step",
            "TTFT p50 (steps)",
        ],
    );
    for &lambda in &[1.0f64, 4.0, 8.0] {
        let mut rng = Rng::new(seed ^ ((lambda * 8.0) as u64) ^ 0x9A6E);
        let trace = gen_prefix_trace(&mut rng, lambda, n_req);
        let off = prefix_sim(&trace, false)?;
        let on = prefix_sim(&trace, true)?;
        anyhow::ensure!(
            off.tokens_by_id == on.tokens_by_id,
            "prefix sharing changed a token stream at λ={lambda}"
        );
        anyhow::ensure!(
            on.prefill_tokens + on.prefill_saved == off.prefill_tokens,
            "prefill accounting leak at λ={lambda}"
        );
        t.row(off.row("off", lambda));
        t.row(on.row("on", lambda));
    }
    Ok(t)
}

/// The prefix sweep as a bench-harness experiment (`cmoe bench --exp
/// prefix`). Artifact-free; exports the repo-root `BENCH_prefix.json`
/// for the cross-PR serving-memory trajectory (also refreshed by
/// `--exp serving`).
pub fn prefix_sweep(ctx: &mut Ctx) -> Result<Table> {
    let t = export_prefix_json(ctx)?;
    ctx.save("prefix", std::slice::from_ref(&t))?;
    Ok(t)
}

fn export_prefix_json(ctx: &mut Ctx) -> Result<Table> {
    let t = prefix_sweep_table(ctx.seed, 120)?;
    let root = crate::util::repo_root().unwrap_or_else(|| ctx.out_dir.clone());
    let path = root.join("BENCH_prefix.json");
    std::fs::write(&path, t.to_json().pretty())
        .with_context(|| format!("write {}", path.display()))?;
    eprintln!("prefix sweep exported to {}", path.display());
    Ok(t)
}

/// The scheduling sweep core (`cmoe bench --exp serving`), artifact-
/// free and fully deterministic: one shared trace per arrival rate,
/// replayed through both scheduling policies.
pub fn serving_sweep_table(seed: u64, n_req: usize) -> Result<Table> {
    let mut t = Table::new(
        "Serving sweep — continuous in-flight batching vs run-to-completion waves \
         (stub model; decode-step metering; buckets {1,8,32}, pool 32)",
        &[
            "Scheduler",
            "λ/step",
            "Requests",
            "Tokens",
            "Decode steps",
            "tok/step",
            "Occupancy",
            "ttft_p50 (steps)",
            "ttft_p99 (steps)",
            "Queue p50 (steps)",
            "tpot_p99 (steps)",
        ],
    );
    for &lambda in &[0.5f64, 2.0, 6.0] {
        let mut rng = Rng::new(seed ^ ((lambda * 16.0) as u64) ^ 0x5EED);
        let trace = gen_trace(&mut rng, lambda, n_req);
        // chunk budget 0: the policy comparison (continuous vs waves)
        // stays isolated from chunking, which has its own sweep
        let cont = continuous_sim(&trace, 0)?;
        let waves = wave_sim(&trace);
        t.row(cont.row("continuous", lambda));
        t.row(waves.row("waves", lambda));
    }
    Ok(t)
}

/// Run a decode-throughput measurement: returns tok/s. Uses the
/// run-to-completion wave path deliberately: Tables 7/9 isolate the
/// dense-vs-MoE *decode kernel* delta, and the wave path keeps KV
/// device-resident (the continuous scheduler's per-slot KV round-trip
/// would measure scheduling overhead instead — that comparison lives
/// in [`serving_sweep`]).
fn measure_tps(
    rt: Arc<crate::runtime::XlaRuntime>,
    model: ModelWeights,
    cfg: EngineConfig,
    batch: usize,
    prompt_len: usize,
    new_tokens: usize,
) -> Result<f64> {
    let engine = Engine::new(rt, model, cfg)?;
    let reqs: Vec<Request> = (0..batch)
        .map(|i| {
            let prompt: Vec<usize> = (0..prompt_len).map(|j| (i * 7 + j * 13) % 250).collect();
            Request::new(
                i as u64,
                prompt,
                GenParams { max_new_tokens: new_tokens, temperature: 0.0, seed: i as u64, stop_token: None },
            )
        })
        .collect();
    // warmup wave (compilation)
    let warm: Vec<Request> = reqs.iter().take(batch).cloned().map(|mut r| {
        r.params.max_new_tokens = 2;
        r
    }).collect();
    engine.run_queue_waves(warm)?;
    engine.metrics.lock().unwrap().waves.clear();
    engine.run_queue_waves(reqs)?;
    let m = engine.metrics.lock().unwrap();
    Ok(m.decode_tps())
}

fn engine_cfg(
    model_name: &str,
    kv_len: usize,
    batch: usize,
    mode: ExecMode,
    spec: Option<MoeSpec>,
) -> EngineConfig {
    let mut cfg = match mode {
        ExecMode::Dense => EngineConfig::dense(model_name, kv_len),
        m => EngineConfig::moe(model_name, kv_len, spec.unwrap(), m),
    };
    cfg.batcher.buckets = vec![batch];
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    cfg
}

/// Shared helper (also used by Table 7): dense-vs-ours decode tok/s.
pub fn decode_throughput(
    ctx: &mut Ctx,
    dense: &ModelWeights,
    ours: &ModelWeights,
    batch: usize,
    kv_len: usize,
) -> Result<(f64, f64)> {
    let rt = ctx.runtime()?;
    let name = ctx.model_name.clone();
    let new_tokens = kv_len / 2 - 2;
    let dense_tps = measure_tps(
        rt.clone(),
        dense.clone(),
        engine_cfg(&name, kv_len, batch, ExecMode::Dense, None),
        batch,
        16,
        new_tokens,
    )?;
    let spec = match &ours.layers[0].ffn {
        crate::model::LayerFfn::Moe(m) => m.spec,
        _ => anyhow::bail!("ours must be converted"),
    };
    let ours_tps = measure_tps(
        rt,
        ours.clone(),
        engine_cfg(&name, kv_len, batch, ExecMode::MoeOrchestrated, Some(spec)),
        batch,
        16,
        new_tokens,
    )?;
    Ok((dense_tps, ours_tps))
}

/// Table 9: inference speedup across SxAyEz configs × context length ×
/// batch regime. Short/long context = KV 64 / 256; memory-bound = b1,
/// compute-bound = b32 (the paper's BS>400 analog on this testbed).
pub fn table9(ctx: &mut Ctx) -> Result<Table> {
    let rt = ctx.runtime()?;
    let name = ctx.model_name.clone();
    let dense = ctx.model()?.clone();
    let mut t = Table::new(
        "Table 9 — decode speedup vs dense (small; orchestrated MoE)",
        &["Config", "Mem-bound b1 ctx64", "Mem-bound b1 ctx256", "Comp-bound b32 ctx64", "Comp-bound b32 ctx256"],
    );
    for spec_s in ["S1A5E8", "S3A3E8", "S2A4E8", "S4A8E16", "S6A6E16", "S3A9E16"] {
        let spec: MoeSpec = spec_s.parse()?;
        let ours = ctx.convert_finetuned(&spec, 2048)?;
        let mut cells = vec![spec_s.to_string()];
        for (batch, kv_len) in [(1usize, 64usize), (1, 256), (32, 64), (32, 256)] {
            let new_tokens = (kv_len / 2 - 2).min(48);
            let d_tps = measure_tps(
                rt.clone(),
                dense.clone(),
                engine_cfg(&name, kv_len, batch, ExecMode::Dense, None),
                batch,
                16,
                new_tokens,
            )?;
            // orchestrated needs prefill_moe which is compiled only for
            // S3A3E8/S1A5E8; fall back to monolithic prefill spec? For
            // S2A4E8 we approximate prefill with the S3A3E8 artifact
            // being absent → run MoeOrchestrated only when compiled.
            let have_prefill = rt.has_artifact(&format!(
                "prefill_moe_{name}_{spec_s}_b{batch}_s16_t{kv_len}"
            ));
            let o_tps = if have_prefill {
                measure_tps(
                    rt.clone(),
                    ours.clone(),
                    engine_cfg(&name, kv_len, batch, ExecMode::MoeOrchestrated, Some(spec)),
                    batch,
                    16,
                    new_tokens,
                )?
            } else {
                f64::NAN
            };
            if o_tps.is_nan() {
                cells.push("n/a".into());
            } else {
                cells.push(speedup(o_tps / d_tps));
            }
        }
        t.row(cells);
    }
    ctx.save("table9", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Figure 5: expert utilization before/after bias adaptation, measured
/// live in the orchestrated engine.
///
/// The balanced clustering already yields near-uniform routing on this
/// checkpoint, so (as a controlled "before" state mirroring the paper's
/// skewed final layer) we plant a +0.3 routing bias on expert 0 of
/// every layer; adaptation must drain it back toward uniform.
pub fn fig5(ctx: &mut Ctx) -> Result<Table> {
    let spec: MoeSpec = "S3A3E8".parse()?;
    let mut ours = ctx.convert(&spec)?;
    for layer in ours.layers.iter_mut() {
        if let crate::model::LayerFfn::Moe(m) = &mut layer.ffn {
            m.gate_bias[0] = 0.3;
        }
    }
    let rt = ctx.runtime()?;
    let name = ctx.model_name.clone();

    let run = |balance: bool| -> Result<Vec<f64>> {
        let mut cfg = engine_cfg(&name, 64, 8, ExecMode::MoeOrchestrated, Some(spec));
        cfg.balance = if balance {
            Some(crate::moe::BalanceConfig { gamma: 5e-3, interval: 1 })
        } else {
            None
        };
        let engine = Engine::new(rt.clone(), ours.clone(), cfg)?;
        // drive enough waves for adaptation to act
        for w in 0..6 {
            let reqs: Vec<Request> = (0..8)
                .map(|i| {
                    let prompt: Vec<usize> =
                        (0..16).map(|j| (w * 31 + i * 7 + j * 13) % 250).collect();
                    Request::new(
                        (w * 8 + i) as u64,
                        prompt,
                        GenParams { max_new_tokens: 16, ..Default::default() },
                    )
                })
                .collect();
            engine.run_queue(reqs)?;
        }
        // measure final-layer utilization spread via a probe wave
        let biases = engine.current_biases();
        let last = biases.last().unwrap().clone();
        Ok(last.iter().map(|&b| b as f64).collect())
    };

    let without = run(false)?;
    let with = run(true)?;

    // measure the utilization each bias vector induces on a probe batch
    // (rust-side routing — identical logic to the engine's)
    let calib = ctx.calib_tokens(crate::data::corpus::Domain::Markov, 4);
    let dense = ctx.model()?.clone();
    let inputs = crate::eval::forward::DenseForward::new(&dense)
        .capture_ffn_inputs(&calib[..256]);
    let last_l = dense.config.n_layers - 1;
    let crate::model::LayerFfn::Moe(moe0) = &ours.layers[last_l].ffn else {
        anyhow::bail!("expected MoE layer");
    };
    let utilization = |biases: &[f64]| -> Vec<f64> {
        let mut m = moe0.clone();
        for (b, &v) in m.gate_bias.iter_mut().zip(biases) {
            *b = v as f32;
        }
        let (_, stats) = crate::moe::moe_ffn_forward(&m, &inputs[last_l]);
        stats.utilization()
    };
    let u_before = utilization(&without);
    let u_after = utilization(&with);

    let mut t = Table::new(
        "Figure 5 — load balancing: final-layer expert utilization (uniform = 1/N_r = 0.2)",
        &["Expert", "util (no adaptation)", "util (γ=5e-3)", "bias (adapted)"],
    );
    for e in 0..without.len() {
        t.row(vec![format!("{e}"), f(u_before[e], 3), f(u_after[e], 3), f(with[e], 4)]);
    }
    let spread = |u: &[f64]| {
        u.iter().cloned().fold(0.0, f64::max) - u.iter().cloned().fold(1.0, f64::min)
    };
    t.row(vec![
        "max-min".into(),
        f(spread(&u_before), 3),
        f(spread(&u_after), 3),
        "-".into(),
    ]);
    ctx.save("fig5", std::slice::from_ref(&t))?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_sweep_continuous_beats_waves() {
        // the acceptance gate: on mixed-length Poisson workloads,
        // continuous batching must deliver ≥ run-to-completion
        // decode-step throughput, and ≥ batch-row occupancy
        let t = serving_sweep_table(0xC0DE, 96).unwrap();
        assert_eq!(t.rows.len(), 6, "3 arrival rates × 2 schedulers");
        for pair in t.rows.chunks(2) {
            let (cont, waves) = (&pair[0], &pair[1]);
            assert_eq!(cont[0], "continuous");
            assert_eq!(waves[0], "waves");
            assert_eq!(cont[1], waves[1], "rows must share λ");
            assert_eq!(cont[3], waves[3], "token totals must match (same trace)");
            let tps_c: f64 = cont[5].parse().unwrap();
            let tps_w: f64 = waves[5].parse().unwrap();
            assert!(
                tps_c >= tps_w,
                "continuous {tps_c} tok/step < waves {tps_w} at λ={}",
                cont[1]
            );
            let occ = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
            assert!(
                occ(&cont[6]) + 1.0 >= occ(&waves[6]),
                "continuous occupancy regressed: {} vs {}",
                cont[6],
                waves[6]
            );
        }
    }

    #[test]
    fn prefix_sweep_shares_without_changing_tokens() {
        // prefix_sweep_table itself enforces the acceptance invariant
        // (bit-identical tokens, exact prefill accounting); here we pin
        // that sharing actually *does* something on this workload
        let t = prefix_sweep_table(0xFACE, 72).unwrap();
        assert_eq!(t.rows.len(), 6, "3 arrival rates × off/on");
        let n = |row: &[String], i: usize| row[i].parse::<u64>().unwrap();
        for pair in t.rows.chunks(2) {
            let (off, on) = (&pair[0], &pair[1]);
            assert_eq!(off[0], "off");
            assert_eq!(on[0], "on");
            assert_eq!(off[1], on[1], "rows must share λ");
            assert_eq!(n(off, 4), 0, "sharing off reuses nothing");
            assert!(
                n(on, 3) < n(off, 3),
                "sharing must prefill strictly fewer tokens at λ={}",
                on[1]
            );
            assert!(n(on, 4) > 0, "no tokens reused at λ={}", on[1]);
        }
        // busiest arrival rate: resident KV pages must drop strictly
        // (one physical copy of each hot system prompt instead of one
        // per live slot); quieter rates only pay the cache's holds
        let (off, on) = (&t.rows[4], &t.rows[5]);
        assert!(
            n(on, 6) < n(off, 6),
            "page high-water did not drop under sharing: {} vs {}",
            on[6],
            off[6]
        );
    }

    #[test]
    fn chunked_sweep_cuts_tail_latency_without_changing_tokens() {
        // token identity and compute equality are enforced inside
        // chunked_sweep_table; this pins the honest headline — the
        // decode-gap tail collapses at every load, the TTFT tail drops
        // at moderate load (arrivals stop waiting out monolithic
        // mega-steps) and stays within a few percent under overload,
        // where queue wait dominates both arms and chunking only
        // reorders equal work (scripts/mirror_chunked_prefill.py
        // replays this exact seed through the python transcription)
        let t = chunked_sweep_table(0xC0DE, 96).unwrap();
        assert_eq!(t.rows.len(), 4, "2 arrival rates × monolithic/chunked");
        let p = |row: &[String], i: usize| row[i].parse::<f64>().unwrap();
        for pair in t.rows.chunks(2) {
            let (mono, chunked) = (&pair[0], &pair[1]);
            assert_eq!(mono[0], "monolithic");
            assert_eq!(chunked[0], "chunked 32");
            assert_eq!(mono[1], chunked[1], "rows must share λ");
            assert_eq!(mono[3], chunked[3], "token totals must match (same streams)");
            assert_eq!(mono[5], chunked[5], "compute totals must match");
            assert!(
                p(chunked, 9) < p(mono, 9),
                "chunking must cut tpot_p99 at λ={}: {} vs {}",
                mono[1],
                chunked[9],
                mono[9]
            );
            assert!(
                p(chunked, 7) <= 1.10 * p(mono, 7),
                "chunking must hold ttft_p99 within 10% at λ={}: {} vs {}",
                mono[1],
                chunked[7],
                mono[7]
            );
        }
        // moderate load: the TTFT tail must drop outright
        let (mono, chunked) = (&t.rows[0], &t.rows[1]);
        assert_eq!(mono[1], "2.0");
        assert!(
            p(chunked, 7) < p(mono, 7),
            "chunking must cut ttft_p99 at moderate load: {} vs {}",
            chunked[7],
            mono[7]
        );
    }

    #[test]
    fn trace_generation_is_poisson_shaped_and_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let ta = gen_trace(&mut a, 2.0, 64);
        let tb = gen_trace(&mut b, 2.0, 64);
        assert_eq!(ta.len(), 64);
        for ((sa, ra), (sb, rb)) in ta.iter().zip(&tb) {
            assert_eq!(sa, sb);
            assert_eq!(ra.prompt, rb.prompt);
            assert_eq!(ra.params.max_new_tokens, rb.params.max_new_tokens);
        }
        // arrivals are non-decreasing and not all at once
        assert!(ta.windows(2).all(|w| w[0].0 <= w[1].0));
        assert!(ta.last().unwrap().0 > 0, "λ=2 should spread 64 arrivals over steps");
    }

    #[test]
    fn dispatch_sweep_runs_and_arena_is_stable() {
        // minimal budget: one timed iteration per cell — this checks
        // structure and the zero-allocation invariant, not speed
        let t = dispatch_sweep_table(7, 1, Duration::ZERO).unwrap();
        assert_eq!(t.rows.len(), 12, "3 specs × 4 batches");
        for row in &t.rows {
            assert_eq!(
                row[8], "0",
                "arena grew during measured steady state: {row:?}"
            );
        }
    }
}
