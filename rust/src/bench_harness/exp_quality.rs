//! Quality experiments: Tables 1–5, 10, 11 and Figures 4, 6 — accuracy
//! and perplexity of CMoE vs the baselines on the substitute workloads.
//! Baseline rows iterate the [`crate::pipeline::registry`] instead of
//! carrying bespoke conversion code: one registry name per table row.

use crate::bench_harness::common::{self, Ctx, CALIB_EXAMPLES, CALIB_SEQ, KA};
use crate::data::corpus::Domain;
use crate::eval::{choice_accuracy, perplexity, self_consistency_accuracy};
use crate::model::{ModelWeights, MoeSpec};
use crate::util::table::{f, Table};
use anyhow::Result;

/// Fine-tune budget every sparsified row shares (paper: 2k samples).
const FT_BUDGET: usize = 2048;

const EVAL_TOKENS: usize = 8 * 1024;

fn eval_row(ctx: &mut Ctx, name: &str, sparsity: &str, model: &ModelWeights) -> Result<Vec<String>> {
    let mut cells = vec![name.to_string(), sparsity.to_string()];
    for suite in ctx.suites() {
        cells.push(f(choice_accuracy(model, &suite) * 100.0, 2));
    }
    let toks = ctx.eval_tokens(Domain::Markov, EVAL_TOKENS);
    cells.push(f(perplexity(model, &toks, CALIB_SEQ), 2));
    Ok(cells)
}

/// Table 1: accuracy at 25% sparsity across methods (S3A3E8 for ours,
/// the registry's matched 6-of-8 budget for baselines; all sparsified
/// methods fine-tuned on the same 2k-sample budget).
pub fn table1(ctx: &mut Ctx) -> Result<Table> {
    let spec: MoeSpec = "S3A3E8".parse()?;
    let baseline_spec: MoeSpec = "S0A6E8".parse()?;
    let dense = ctx.model()?.clone();
    let profiles = ctx.profiles(Domain::Markov, CALIB_EXAMPLES, KA)?;

    let mut t = Table::new(
        "Table 1 — accuracy (%) at 25% FFN sparsity (small, 2k-sample FT)",
        &["Method", "Sp.", "Knowledge", "Arith", "Pattern", "PPL(markov)"],
    );
    t.row(eval_row(ctx, "Dense", "0%", &dense)?);

    // structured pruning (SliceGPT/SLEB stand-in, 20% FFN removal)
    let pruned = common::pruned_model(&dense, &profiles, 0.20);
    t.row(eval_row(ctx, "Pruning-20%", "20%", &pruned)?);

    // baselines at matched FLOP budget, straight from the registry
    for (label, method) in [
        ("LLaMA-MoE", "llama-moe"),
        ("MoEfication", "moefication"),
        ("G-MoEfication", "gmoefication"),
        ("EMoE", "emoe"),
    ] {
        let m = ctx.convert_method(method, &baseline_spec, FT_BUDGET)?;
        t.row(eval_row(ctx, label, "25%", &m)?);
    }

    let ours = ctx.convert_finetuned(&spec, FT_BUDGET)?;
    t.row(eval_row(ctx, "Ours (CMoE)", "25%", &ours)?);

    ctx.save("table1", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Table 2: the harder "knowledge / coding / math" analog — here the
/// same three families at higher item difficulty (longer contexts).
pub fn table2(ctx: &mut Ctx) -> Result<Table> {
    use crate::data::tasks_gen::{gen_choice_tasks, TaskFamily};
    use crate::eval::tasks::TaskSuite;
    let spec: MoeSpec = "S3A3E8".parse()?;
    let dense = ctx.model()?.clone();
    let ours = ctx.convert_finetuned(&spec, 2048)?;
    let suites: Vec<TaskSuite> = [
        (TaskFamily::Knowledge, "Knowledge(hard)"),
        (TaskFamily::Arith, "Arith(hard)"),
        (TaskFamily::Pattern, "Pattern(hard)"),
    ]
    .iter()
    .map(|(fam, name)| TaskSuite {
        name: name.to_string(),
        tasks: gen_choice_tasks(*fam, 120, ctx.seed ^ 0x7AB2),
    })
    .collect();

    let mut t = Table::new(
        "Table 2 — broader evaluation (small, 25% sparsity S3A3E8)",
        &["Method", "Knowledge(hard)", "Arith(hard)", "Pattern(hard)"],
    );
    for (name, m) in [("Dense", &dense), ("Ours (CMoE)", &ours)] {
        let mut cells = vec![name.to_string()];
        for s in &suites {
            cells.push(f(choice_accuracy(m, s) * 100.0, 2));
        }
        t.row(cells);
    }
    ctx.save("table2", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Table 3: training-free vs fine-tuned.
pub fn table3(ctx: &mut Ctx) -> Result<Table> {
    let spec: MoeSpec = "S3A3E8".parse()?;
    let tf = ctx.convert(&spec)?;
    let ft = ctx.convert_finetuned(&spec, 2048)?;
    let markov = ctx.eval_tokens(Domain::Markov, EVAL_TOKENS);
    let arith = ctx.eval_tokens(Domain::Arith, EVAL_TOKENS);
    let suites = ctx.suites();

    let mut t = Table::new(
        "Table 3 — training-free vs fine-tuned (small, 25% sparsity)",
        &["Method", "Regime", "AvgAcc (%)", "PPL markov", "PPL arith"],
    );
    let dense = ctx.model()?.clone();
    for (name, regime, m) in [
        ("Dense", "—", &dense),
        ("Ours", "Training-free", &tf),
        ("Ours", "Fine-tuned (2k)", &ft),
    ] {
        let avg: f64 =
            suites.iter().map(|s| choice_accuracy(m, s)).sum::<f64>() / suites.len() as f64;
        t.row(vec![
            name.into(),
            regime.into(),
            f(avg * 100.0, 2),
            f(perplexity(m, &markov, CALIB_SEQ), 2),
            f(perplexity(m, &arith, CALIB_SEQ), 2),
        ]);
    }
    ctx.save("table3", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Table 4: calibration sensitivity — source domain × example count,
/// plus the shared-expert domain-overlap measurement.
pub fn table4(ctx: &mut Ctx) -> Result<Table> {
    let spec: MoeSpec = "S3A3E8".parse()?;
    let markov_eval = ctx.eval_tokens(Domain::Markov, EVAL_TOKENS);
    let arith_eval = ctx.eval_tokens(Domain::Arith, EVAL_TOKENS);
    let mut t = Table::new(
        "Table 4 — calibration sensitivity (small, 25% sparsity)",
        &["Source", "n", "AvgAcc (%)", "PPL markov", "PPL arith"],
    );
    for domain in [Domain::Markov, Domain::Arith] {
        for n in [4usize, 8, 16] {
            let profiles = ctx.profiles(domain, n, KA)?;
            let dense = ctx.model()?.clone();
            let conv = crate::converter::convert_model(
                &dense,
                &profiles,
                &spec,
                &crate::converter::ConvertOptions::default(),
            )?;
            let mut m = conv.model;
            let calib = ctx.calib_tokens(domain, n);
            common::finetune_model(&mut m, &dense, &calib, 2048, CALIB_SEQ)?;
            let suites = ctx.suites();
            let avg: f64 =
                suites.iter().map(|s| choice_accuracy(&m, s)).sum::<f64>() / suites.len() as f64;
            t.row(vec![
                domain.name().into(),
                format!("{n}"),
                f(avg * 100.0, 2),
                f(perplexity(&m, &markov_eval, CALIB_SEQ), 2),
                f(perplexity(&m, &arith_eval, CALIB_SEQ), 2),
            ]);
        }
    }
    // domain invariance of the shared experts (paper: 80–86% overlap)
    let pa = ctx.profiles(Domain::Markov, CALIB_EXAMPLES, KA)?;
    let pb = ctx.profiles(Domain::Arith, CALIB_EXAMPLES, KA)?;
    let d_ff = ctx.model()?.config.d_ff;
    let shared_n = spec.shared * (d_ff / spec.total);
    let overlap: f64 = pa
        .iter()
        .zip(&pb)
        .map(|(a, b)| a.shared_overlap(b, shared_n))
        .sum::<f64>()
        / pa.len() as f64;
    t.row(vec![
        "overlap(markov,arith)".into(),
        "-".into(),
        f(overlap * 100.0, 1),
        "-".into(),
        "-".into(),
    ]);
    ctx.save("table4", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Table 5: clustering × routing ablation (grouping and router of each
/// row are registry entries; the "+ ours" rows are the registry's
/// `<base>+cmoe-router` hybrids).
pub fn table5(ctx: &mut Ctx) -> Result<Table> {
    let baseline_spec: MoeSpec = "S0A6E8".parse()?;
    let suites = ctx.suites();

    let mut t = Table::new(
        "Table 5 — clustering and routing ablation (small, 25% sparsity, 2k FT)",
        &["Method", "Grouping", "Router", "AvgAcc (%)"],
    );
    let rows: &[(&str, &str, &str, &str, MoeSpec)] = &[
        ("MoEfication", "moefication", "Param K-means", "Linear", baseline_spec),
        ("Read-ME", "readme", "Domain-aware", "Global", baseline_spec),
        ("MoEfication + ours", "moefication+cmoe-router", "Param K-means", "Analytical", baseline_spec),
        ("Read-ME + ours", "readme+cmoe-router", "Domain-aware", "Analytical", baseline_spec),
        ("Ours", "cmoe", "Activation + shared", "Analytical", "S3A3E8".parse()?),
    ];
    for &(label, method, grouping, router, spec) in rows {
        let m = ctx.convert_method(method, &spec, FT_BUDGET)?;
        let avg: f64 =
            suites.iter().map(|s| choice_accuracy(&m, s)).sum::<f64>() / suites.len() as f64;
        t.row(vec![
            label.to_string(),
            grouping.to_string(),
            router.to_string(),
            f(avg * 100.0, 2),
        ]);
    }

    ctx.save("table5", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Table 10: perplexity vs sparsity with 16 experts.
pub fn table10(ctx: &mut Ctx) -> Result<Table> {
    let toks = ctx.eval_tokens(Domain::Markov, EVAL_TOKENS);
    let dense = ctx.model()?.clone();
    let mut t = Table::new(
        "Table 10 — perplexity vs sparsity (small, 16 experts)",
        &["Config", "Sparsity", "PPL"],
    );
    t.row(vec!["Dense".into(), "0".into(), f(perplexity(&dense, &toks, CALIB_SEQ), 3)]);
    // S4 shared fixed; sweep active routed experts
    for (spec_s, sp) in [
        ("S4A2E16", "0.625"),
        ("S4A4E16", "0.5"),
        ("S4A6E16", "0.375"),
        ("S4A8E16", "0.25"),
        ("S4A10E16", "0.125"),
    ] {
        let spec: MoeSpec = spec_s.parse()?;
        let m = ctx.convert_finetuned(&spec, 2048)?;
        t.row(vec![spec_s.into(), sp.into(), f(perplexity(&m, &toks, CALIB_SEQ), 3)]);
    }
    ctx.save("table10", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Table 11: k-sample self-consistency.
pub fn table11(ctx: &mut Ctx) -> Result<Table> {
    let dense = ctx.model()?.clone();
    let ours = ctx.convert_finetuned(&"S3A3E8".parse()?, 2048)?;
    let suites = ctx.suites();
    let mut t = Table::new(
        "Table 11 — k-sample self-consistency (small, 25% sparsity)",
        &["Method", "k", "Knowledge", "Arith", "Pattern", "Avg"],
    );
    for (name, m) in [("Dense", &dense), ("Ours", &ours)] {
        for (k, temp) in [(1usize, 0.0f32), (5, 0.7)] {
            let mut cells = vec![name.to_string(), format!("{k}")];
            let mut accs = Vec::new();
            for s in &suites {
                let a = self_consistency_accuracy(m, s, k, temp, ctx.seed ^ k as u64);
                accs.push(a);
                cells.push(f(a * 100.0, 2));
            }
            cells.push(f(accs.iter().sum::<f64>() / accs.len() as f64 * 100.0, 2));
            t.row(cells);
        }
    }
    ctx.save("table11", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Figure 4: data efficiency — accuracy/PPL vs fine-tuning samples.
pub fn fig4(ctx: &mut Ctx) -> Result<Table> {
    let spec: MoeSpec = "S3A3E8".parse()?;
    let toks = ctx.eval_tokens(Domain::Markov, EVAL_TOKENS);
    let suites = ctx.suites();
    let mut t = Table::new(
        "Figure 4 — data efficiency (small, 25% sparsity)",
        &["FT samples", "AvgAcc (%)", "PPL markov"],
    );
    for samples in [0usize, 256, 512, 1024, 2048] {
        let m = if samples == 0 {
            ctx.convert(&spec)?
        } else {
            ctx.convert_finetuned(&spec, samples)?
        };
        let avg: f64 =
            suites.iter().map(|s| choice_accuracy(&m, s)).sum::<f64>() / suites.len() as f64;
        t.row(vec![
            format!("{samples}"),
            f(avg * 100.0, 2),
            f(perplexity(&m, &toks, CALIB_SEQ), 2),
        ]);
    }
    ctx.save("fig4", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Figure 6: expert-configuration impact at fixed 25% sparsity.
pub fn fig6(ctx: &mut Ctx) -> Result<Table> {
    let suites = ctx.suites();
    let mut t = Table::new(
        "Figure 6 — expert configuration impact (25% sparsity)",
        &["Config", "Knowledge", "Arith", "Pattern"],
    );
    for spec_s in ["S1A5E8", "S3A3E8", "S2A4E8", "S4A8E16", "S6A6E16", "S3A9E16"] {
        let spec: MoeSpec = spec_s.parse()?;
        let m = ctx.convert_finetuned(&spec, 2048)?;
        let mut cells = vec![spec_s.to_string()];
        for s in &suites {
            cells.push(f(choice_accuracy(&m, s) * 100.0, 2));
        }
        t.row(cells);
    }
    ctx.save("fig6", std::slice::from_ref(&t))?;
    Ok(t)
}
