//! Efficiency experiments: Tables 6–8 — conversion cost, FLOPs/MACs,
//! and composition with WINA neuron sparsity.

use crate::bench_harness::common::{self, Ctx, CALIB_EXAMPLES, CALIB_SEQ, KA};
use crate::data::corpus::Domain;
use crate::eval::flops::count_flops;
use crate::model::MoeSpec;
use crate::util::table::{f, pct, Table};
use crate::util::Timer;
use anyhow::Result;

/// Table 6: token budget and conversion time. We measure our analytical
/// construction + fine-tuning wall-clock and contrast with the
/// baselines' *measured* construction plus their published training
/// budgets (which cannot be run here and are quoted as reported).
pub fn table6(ctx: &mut Ctx) -> Result<Table> {
    let dense = ctx.model()?.clone();
    let profiles = ctx.profiles(Domain::Markov, CALIB_EXAMPLES, KA)?;
    let calib = ctx.calib_tokens(Domain::Markov, CALIB_EXAMPLES);
    let spec: MoeSpec = "S3A3E8".parse()?;

    // ours: construct + fine-tune, timed
    let timer = Timer::start();
    let conv = crate::converter::convert_model(
        &dense,
        &profiles,
        &spec,
        &crate::converter::ConvertOptions::default(),
    )?;
    let construct = timer.total();
    let mut m = conv.model;
    let t2 = Timer::start();
    common::finetune_model(&mut m, &dense, &calib, 2048, CALIB_SEQ)?;
    let ft = t2.total();

    // llama-moe-style split (measured split time; training budget quoted)
    let baseline_spec: MoeSpec = "S0A6E8".parse()?;
    let calib_spec = ctx.calib_spec(Domain::Markov, CALIB_EXAMPLES, KA);
    let t3 = Timer::start();
    let _ = crate::pipeline::Pipeline::for_method("llama-moe")?
        .spec(baseline_spec)
        .calib(calib_spec.clone())
        .run(&dense)?;
    let lm_time = t3.total();

    let t4 = Timer::start();
    let _ = crate::pipeline::Pipeline::for_method("moefication")?
        .spec(baseline_spec)
        .calib(calib_spec)
        .run(&dense)?;
    let moef_time = t4.total();

    let calib_tokens = CALIB_EXAMPLES * CALIB_SEQ + 2048;
    let mut t = Table::new(
        "Table 6 — token budget and conversion time (small)",
        &["Method", "Token budget", "Construct", "E2E (this testbed)"],
    );
    t.row(vec![
        "Ours (CMoE)".into(),
        format!("{calib_tokens} tok"),
        crate::util::timer::fmt_duration(construct),
        crate::util::timer::fmt_duration(construct + ft),
    ]);
    t.row(vec![
        "LLaMA-MoE (split only)".into(),
        "200B tok (paper)".into(),
        crate::util::timer::fmt_duration(lm_time),
        "weeks (paper)".into(),
    ]);
    t.row(vec![
        "MoEfication (split+router)".into(),
        "router-train corpus".into(),
        crate::util::timer::fmt_duration(moef_time),
        crate::util::timer::fmt_duration(moef_time),
    ]);
    t.row(vec![
        "  per-stage (ours)".into(),
        format!(
            "shared {} | cluster {} | router {}",
            crate::util::timer::fmt_duration(conv.report.shared_select),
            crate::util::timer::fmt_duration(conv.report.clustering),
            crate::util::timer::fmt_duration(conv.report.router),
        ),
        crate::util::timer::fmt_duration(conv.report.slicing),
        "-".into(),
    ]);
    ctx.save("table6", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Table 7: FLOPs / MACs / measured decode throughput, dense vs ours
/// (plus the hierarchical variant's analytic fraction).
pub fn table7(ctx: &mut Ctx) -> Result<Table> {
    let dense = ctx.model()?.clone();
    let spec: MoeSpec = "S3A3E8".parse()?;
    let ours = ctx.convert_finetuned(&spec, 2048)?;

    let rd = count_flops(&dense, 1.0);
    let rm = count_flops(&ours, 1.0);

    // measured throughput via the serving engine (compute-bound: b=32)
    let tput = super::exp_serving::decode_throughput(ctx, &dense, &ours, 32, 64)?;

    let mut t = Table::new(
        "Table 7 — efficiency (small; throughput measured, b=32 ctx=64)",
        &["Model", "Method", "MFLOPs/tok", "MMACs/tok", "Thru (tok/s)"],
    );
    t.row(vec![
        "small".into(),
        "Dense".into(),
        f(rd.flops_total() / 1e6, 2),
        f(rd.macs_total() / 1e6, 2),
        f(tput.0, 1),
    ]);
    t.row(vec![
        "small".into(),
        format!("Ours (25%) {}", pct(-rm.savings_vs(&rd))),
        f(rm.flops_total() / 1e6, 2),
        f(rm.macs_total() / 1e6, 2),
        format!("{} ({})", f(tput.1, 1), pct(tput.1 / tput.0 - 1.0)),
    ]);
    // hierarchical: analytic only (sub-restructure each expert S1A2E4)
    let profiles = ctx.profiles(Domain::Markov, CALIB_EXAMPLES, KA)?;
    let sub: MoeSpec = "S1A2E4".parse()?;
    if let crate::model::LayerFfn::Moe(moe0) = &ours.layers[0].ffn {
        let hier = crate::converter::hierarchical_convert(
            moe0,
            &profiles[0],
            &sub,
            &crate::converter::ConvertOptions::default(),
        )?;
        let frac = hier.active_fraction();
        let d = dense.config.d_model as f64;
        let ffn_dense = 3.0 * d * dense.config.d_ff as f64;
        let saved = 1.0 - frac;
        t.row(vec![
            "small".into(),
            format!("Ours (hier. S3A3E8×S1A2E4)"),
            format!("FFN MACs ×{:.3} ({} vs dense)", frac, pct(-saved)),
            f(ffn_dense * frac / 1e6, 3),
            "-".into(),
        ]);
    }
    ctx.save("table7", std::slice::from_ref(&t))?;
    Ok(t)
}

/// Table 8: orthogonality with WINA neuron-level sparsity.
pub fn table8(ctx: &mut Ctx) -> Result<Table> {
    let dense = ctx.model()?.clone();
    let spec: MoeSpec = "S3A3E8".parse()?;
    let ours = ctx.convert_finetuned(&spec, 2048)?;

    let rd = count_flops(&dense, 1.0);
    let r_wina = count_flops(&dense, 0.75);
    let r_ours = count_flops(&ours, 1.0);
    let r_both = count_flops(&ours, 0.75);

    // quality impact of the composition (PPL)
    let toks = ctx.eval_tokens(Domain::Markov, 4096);
    let ppl_dense = crate::eval::perplexity(&dense, &toks, CALIB_SEQ);
    let wina_model = apply_wina_eval(&dense, &toks, 0.75)?;
    let ppl_ours = crate::eval::perplexity(&ours, &toks, CALIB_SEQ);

    let mut t = Table::new(
        "Table 8 — orthogonality with WINA (small, 25% expert sparsity, 75% neuron keep)",
        &["Method", "MFLOPs/tok", "Δ vs dense", "PPL markov"],
    );
    t.row(vec!["Dense".into(), f(rd.flops_total() / 1e6, 2), "—".into(), f(ppl_dense, 2)]);
    t.row(vec![
        "WINA (25% neuron sparsity)".into(),
        f(r_wina.flops_total() / 1e6, 2),
        pct(-r_wina.savings_vs(&rd)),
        f(wina_model, 2),
    ]);
    t.row(vec![
        "Ours (25% expert sparsity)".into(),
        f(r_ours.flops_total() / 1e6, 2),
        pct(-r_ours.savings_vs(&rd)),
        f(ppl_ours, 2),
    ]);
    t.row(vec![
        "Ours + WINA".into(),
        f(r_both.flops_total() / 1e6, 2),
        pct(-r_both.savings_vs(&rd)),
        "composed (see docs/ARCHITECTURE.md)".into(),
    ]);
    ctx.save("table8", std::slice::from_ref(&t))?;
    Ok(t)
}

/// PPL of the dense model with WINA applied inside every FFN.
fn apply_wina_eval(model: &crate::model::ModelWeights, toks: &[usize], keep: f32) -> Result<f64> {
    // evaluate by monkey-layer: clone model, evaluate with a custom
    // forward that masks FFN hidden states (wina_ffn_forward)
    use crate::tensor::{self, Tensor};
    let cfg = &model.config;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for chunk in toks.chunks(CALIB_SEQ) {
        if chunk.len() < 2 {
            continue;
        }
        let q = chunk.len();
        let d = cfg.d_model;
        let mut x = Tensor::zeros(&[q, d]);
        for (t, &id) in chunk.iter().enumerate() {
            let e = model.embed.row(id);
            let p = model.pos.row(t);
            let row = x.row_mut(t);
            for j in 0..d {
                row[j] = e[j] + p[j];
            }
        }
        for layer in &model.layers {
            let xn = tensor::rmsnorm_rows(&x, &layer.attn_norm, 1e-6);
            let attn = crate::eval::forward::attention_for_tests(&xn, layer, cfg.n_heads);
            tensor::add_inplace(&mut x, &attn);
            let xn = tensor::rmsnorm_rows(&x, &layer.ffn_norm, 1e-6);
            if let crate::model::LayerFfn::Dense(ffn) = &layer.ffn {
                let y = crate::baselines::wina_ffn_forward(ffn, &xn, keep);
                tensor::add_inplace(&mut x, &y);
            }
        }
        let xn = tensor::rmsnorm_rows(&x, &model.final_norm, 1e-6);
        let logits = tensor::matmul(&xn, &model.unembed);
        for t in 0..q - 1 {
            let row = logits.row(t);
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
            total += (lse - row[chunk[t + 1]]) as f64;
            count += 1;
        }
    }
    Ok((total / count as f64).exp())
}
