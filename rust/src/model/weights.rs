//! Weight containers for dense and MoE-restructured models.
//!
//! Conventions (matching `python/compile/model.py`):
//! * All projection matrices are stored **input-major**: `w: [d_in, d_out]`
//!   and applied as `y = x @ w`.
//! * FFN: `w_gate, w_up: [d, d_h]`, `w_down: [d_h, d]` (Eq. 3).
//! * A *neuron* `i` is the triple (`w_gate[:, i]`, `w_up[:, i]`,
//!   `w_down[i, :]`); expert slices carve neurons out of these matrices.

use crate::model::{MoeSpec, TransformerConfig};
use crate::tensor::Tensor;

/// Attention projections for one layer.
#[derive(Clone, Debug)]
pub struct AttnWeights {
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
}

/// Dense SwiGLU FFN weights (one layer, or one expert slice).
#[derive(Clone, Debug)]
pub struct FfnWeights {
    pub w_gate: Tensor,
    pub w_up: Tensor,
    pub w_down: Tensor,
}

impl FfnWeights {
    /// Hidden (neuron) dimension of this FFN / expert.
    pub fn hidden_dim(&self) -> usize {
        self.w_gate.shape[1]
    }

    /// Carve the neuron subset `idx` into a standalone FFN (expert).
    pub fn slice_neurons(&self, idx: &[usize]) -> FfnWeights {
        FfnWeights {
            w_gate: self.w_gate.select_cols(idx),
            w_up: self.w_up.select_cols(idx),
            w_down: self.w_down.select_rows(idx),
        }
    }
}

/// Analytical router weights: the representative-neuron columns
/// (Eq. 8) — `w_gate_r, w_up_r: [d, N_r]`.
#[derive(Clone, Debug)]
pub struct RouterWeights {
    pub w_gate_r: Tensor,
    pub w_up_r: Tensor,
}

/// Router variants. CMoE uses [`Router::Analytical`]; the MoEfication /
/// LLaMA-MoE baselines (and the Table 5 ablation) use a trained
/// [`Router::Linear`] MLP scoring head.
#[derive(Clone, Debug)]
pub enum Router {
    /// Representative-neuron SwiGLU scores (Eq. 8), training-free.
    Analytical(RouterWeights),
    /// Learned linear scorer `s = x @ w`, `w: [d, N_r]`.
    Linear(Tensor),
}

impl Router {
    /// Router scores for a batch `x: [q, d]` → `[q, N_r]`.
    pub fn scores(&self, x: &Tensor) -> Tensor {
        match self {
            Router::Analytical(r) => crate::tensor::swiglu_hidden(x, &r.w_gate_r, &r.w_up_r),
            Router::Linear(w) => crate::tensor::matmul(x, w),
        }
    }

    pub fn n_routed(&self) -> usize {
        match self {
            Router::Analytical(r) => r.w_gate_r.shape[1],
            Router::Linear(w) => w.shape[1],
        }
    }
}

/// A CMoE-restructured FFN layer: shared expert + routed experts +
/// analytical router + gate parameters (Eq. 4/8/9).
#[derive(Clone, Debug)]
pub struct MoeLayerWeights {
    pub spec: MoeSpec,
    /// Merged shared expert (the `N_s` shared experts are contiguous in
    /// one slice — they always fire together, so they are fused).
    pub shared: FfnWeights,
    /// `N_r` routed experts of `m` neurons each.
    pub experts: Vec<FfnWeights>,
    pub router: Router,
    /// Learnable gate scaling `u` (init 0 ⇒ gates start at exactly 1).
    pub gate_scale: Vec<f32>,
    /// Load-balancing bias `b` added to scores pre-top-k (not to gates).
    pub gate_bias: Vec<f32>,
    /// Original-FFN neuron index of every shared neuron (bookkeeping:
    /// conversion must be a permutation; tests rely on this).
    pub shared_neurons: Vec<usize>,
    /// Original neuron indices per routed expert.
    pub expert_neurons: Vec<Vec<usize>>,
    /// Representative neuron (original index) per routed expert.
    pub representatives: Vec<usize>,
    /// G-MoEfication-style compensation: the calibration-mean output
    /// `E[E_i(x)]` of each routed expert, added for *deactivated*
    /// experts instead of zero (None for plain CMoE / MoEfication).
    pub compensation: Option<Vec<Vec<f32>>>,
}

impl MoeLayerWeights {
    /// All original neuron indices covered by this layer, sorted.
    pub fn covered_neurons(&self) -> Vec<usize> {
        let mut all: Vec<usize> = self
            .shared_neurons
            .iter()
            .copied()
            .chain(self.expert_neurons.iter().flatten().copied())
            .collect();
        all.sort_unstable();
        all
    }
}

/// FFN slot of a layer: still dense, or restructured.
#[derive(Clone, Debug)]
pub enum LayerFfn {
    Dense(FfnWeights),
    Moe(MoeLayerWeights),
}

/// One transformer block.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub attn: AttnWeights,
    pub ffn_norm: Vec<f32>,
    pub ffn: LayerFfn,
}

/// A full model.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub config: TransformerConfig,
    pub embed: Tensor,
    /// Learned absolute position embeddings `[max_seq, d]`.
    pub pos: Tensor,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    pub unembed: Tensor,
}

impl ModelWeights {
    /// Load from a `.cmw` file (see [`crate::model::read_cmw`]).
    pub fn load(path: impl AsRef<std::path::Path>) -> crate::Result<ModelWeights> {
        crate::model::format::load_model(path.as_ref())
    }

    /// Save to a `.cmw` file. MoE layers round-trip completely
    /// (expert slices, router, gate parameters, neuron bookkeeping).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> crate::Result<()> {
        crate::model::format::save_model(self, path.as_ref())
    }

    /// Borrow the dense FFN of layer `l` (panics on MoE layers — used by
    /// conversion, which runs before restructuring).
    pub fn dense_ffn(&self, l: usize) -> &FfnWeights {
        match &self.layers[l].ffn {
            LayerFfn::Dense(f) => f,
            LayerFfn::Moe(_) => panic!("layer {l} already restructured"),
        }
    }

    /// Generate a random dense model (used by tests and throughput
    /// benches where trained weights don't matter).
    pub fn random(config: &TransformerConfig, rng: &mut crate::util::Rng) -> ModelWeights {
        let d = config.d_model;
        let dh = config.d_ff;
        let v = config.vocab;
        let std_e = 0.02;
        let std_p = (1.0 / d as f32).sqrt();
        let layers = (0..config.n_layers)
            .map(|_| LayerWeights {
                attn_norm: vec![1.0; d],
                attn: AttnWeights {
                    wq: Tensor::randn(rng, &[d, d], std_p),
                    wk: Tensor::randn(rng, &[d, d], std_p),
                    wv: Tensor::randn(rng, &[d, d], std_p),
                    wo: Tensor::randn(rng, &[d, d], std_p),
                },
                ffn_norm: vec![1.0; d],
                ffn: LayerFfn::Dense(FfnWeights {
                    w_gate: Tensor::randn(rng, &[d, dh], std_p),
                    w_up: Tensor::randn(rng, &[d, dh], std_p),
                    w_down: Tensor::randn(rng, &[dh, d], std_p),
                }),
            })
            .collect();
        ModelWeights {
            config: config.clone(),
            embed: Tensor::randn(rng, &[v, d], std_e),
            pos: Tensor::randn(rng, &[config.max_seq, d], std_e),
            layers,
            final_norm: vec![1.0; d],
            unembed: Tensor::randn(rng, &[d, v], std_p),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::model_config;
    use crate::util::Rng;

    #[test]
    fn slice_neurons_shapes() {
        let mut rng = Rng::new(1);
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[8, 32], 1.0),
            w_up: Tensor::randn(&mut rng, &[8, 32], 1.0),
            w_down: Tensor::randn(&mut rng, &[32, 8], 1.0),
        };
        let e = ffn.slice_neurons(&[1, 5, 9, 30]);
        assert_eq!(e.w_gate.shape, vec![8, 4]);
        assert_eq!(e.w_up.shape, vec![8, 4]);
        assert_eq!(e.w_down.shape, vec![4, 8]);
        assert_eq!(e.hidden_dim(), 4);
        // column 1 of slice == column 5 of original
        for r in 0..8 {
            assert_eq!(e.w_gate.at2(r, 1), ffn.w_gate.at2(r, 5));
        }
        assert_eq!(e.w_down.row(2), ffn.w_down.row(9));
    }

    #[test]
    fn random_model_shapes() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(2);
        let m = ModelWeights::random(&cfg, &mut rng);
        assert_eq!(m.layers.len(), cfg.n_layers);
        assert_eq!(m.embed.shape, vec![cfg.vocab, cfg.d_model]);
        assert_eq!(m.dense_ffn(0).hidden_dim(), cfg.d_ff);
    }
}
