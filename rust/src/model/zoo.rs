//! The model zoo: named configurations used throughout tests, examples
//! and the bench harness. `small` is the checkpoint pretrained at
//! artifact-build time (python/compile/pretrain.py); `tiny` is for fast
//! tests; `base` is the larger throughput-bench config.

use crate::model::TransformerConfig;
use anyhow::{bail, Result};

/// (name, vocab, d_model, n_layers, n_heads, d_ff, max_seq)
pub const MODEL_ZOO: &[(&str, usize, usize, usize, usize, usize, usize)] = &[
    // d_ff divisible by 8 and 16 so every SxAyEz config in the paper fits
    ("tiny", 256, 64, 2, 4, 256, 128),
    ("small", 256, 128, 4, 4, 512, 256),
    ("base", 256, 256, 6, 8, 1024, 256),
];

/// Look up a zoo config by name.
pub fn model_config(name: &str) -> Result<TransformerConfig> {
    for &(n, vocab, d_model, n_layers, n_heads, d_ff, max_seq) in MODEL_ZOO {
        if n == name {
            return Ok(TransformerConfig {
                name: n.to_string(),
                vocab,
                d_model,
                n_layers,
                n_heads,
                d_ff,
                max_seq,
            });
        }
    }
    bail!("unknown model '{name}' (zoo: {:?})", MODEL_ZOO.iter().map(|z| z.0).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_lookup() {
        let c = model_config("small").unwrap();
        assert_eq!(c.d_ff, 512);
        assert!(model_config("nonexistent").is_err());
    }

    #[test]
    fn all_zoo_configs_divisible_by_16_experts() {
        for &(name, ..) in MODEL_ZOO {
            let c = model_config(name).unwrap();
            assert_eq!(c.d_ff % 16, 0, "{name}: d_ff={} not divisible by 16", c.d_ff);
            assert_eq!(c.d_model % c.n_heads, 0, "{name}: head dim fractional");
        }
    }
}
