//! `.cmw` — the CMoE weight file format.
//!
//! Layout (little-endian):
//! ```text
//! magic   "CMW1"            4 bytes
//! hlen    u64               header byte length
//! header  JSON              { "config": {...}, "tensors": {name: {shape, offset}},
//!                             "meta": {...} }
//! data    f32[]             concatenated tensor payloads, 64-byte aligned start
//! ```
//! The python build path (`python/compile/pretrain.py`) writes the same
//! format with numpy so the rust side can load trained checkpoints
//! without any python at runtime.

use crate::model::weights::*;
use crate::model::{MoeSpec, TransformerConfig};
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"CMW1";
const ALIGN: usize = 64;

/// An open `.cmw` file: named tensors + free-form meta.
pub struct CmwFile {
    pub tensors: BTreeMap<String, Tensor>,
    pub config: Json,
    pub meta: Json,
}

/// Write named tensors with a config/meta header.
pub fn write_cmw(
    path: &Path,
    config: &Json,
    meta: &Json,
    tensors: &BTreeMap<String, Tensor>,
) -> Result<()> {
    let mut offset = 0usize;
    let mut theader = Json::obj();
    for (name, t) in tensors {
        let mut e = Json::obj();
        e.set("shape", t.shape.clone());
        e.set("offset", offset);
        theader.set(name, e);
        offset += t.numel() * 4;
    }
    let mut header = Json::obj();
    header.set("config", config.clone());
    header.set("meta", meta.clone());
    header.set("tensors", theader);
    let hbytes = header.to_string().into_bytes();

    let data_start = 4 + 8 + hbytes.len();
    let pad = (ALIGN - data_start % ALIGN) % ALIGN;

    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&((hbytes.len() + pad) as u64).to_le_bytes())?;
    f.write_all(&hbytes)?;
    f.write_all(&vec![b' '; pad])?;
    for t in tensors.values() {
        // SAFETY-free: serialize f32s explicitly as LE bytes
        let mut buf = Vec::with_capacity(t.numel() * 4);
        for v in &t.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    f.flush()?;
    Ok(())
}

/// Read a `.cmw` file fully into memory.
pub fn read_cmw(path: &Path) -> Result<CmwFile> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a CMW1 file", path.display());
    }
    let mut hlen = [0u8; 8];
    f.read_exact(&mut hlen)?;
    let hlen = u64::from_le_bytes(hlen) as usize;
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?.trim_end())
        .with_context(|| "parse cmw header")?;
    let mut rest = Vec::new();
    f.read_to_end(&mut rest)?;

    let mut tensors = BTreeMap::new();
    let tmap = header.get("tensors").as_obj().context("tensors key")?;
    for (name, entry) in tmap {
        let shape: Vec<usize> = entry
            .get("shape")
            .as_arr()
            .context("shape")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        let offset = entry.get("offset").as_usize().context("offset")?;
        let numel: usize = shape.iter().product();
        let end = offset + numel * 4;
        if end > rest.len() {
            bail!("tensor {name} out of bounds ({end} > {})", rest.len());
        }
        let mut data = Vec::with_capacity(numel);
        for c in rest[offset..end].chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        tensors.insert(name.clone(), Tensor::from_vec(data, &shape));
    }
    Ok(CmwFile { tensors, config: header.get("config").clone(), meta: header.get("meta").clone() })
}

// ---------------------------------------------------------------------------
// Model-level (de)serialization
// ---------------------------------------------------------------------------

fn config_to_json(c: &TransformerConfig) -> Json {
    let mut j = Json::obj();
    j.set("name", c.name.as_str())
        .set("vocab", c.vocab)
        .set("d_model", c.d_model)
        .set("n_layers", c.n_layers)
        .set("n_heads", c.n_heads)
        .set("d_ff", c.d_ff)
        .set("max_seq", c.max_seq);
    j
}

fn config_from_json(j: &Json) -> Result<TransformerConfig> {
    Ok(TransformerConfig {
        name: j.get("name").as_str().unwrap_or("unnamed").to_string(),
        vocab: j.get("vocab").as_usize().context("vocab")?,
        d_model: j.get("d_model").as_usize().context("d_model")?,
        n_layers: j.get("n_layers").as_usize().context("n_layers")?,
        n_heads: j.get("n_heads").as_usize().context("n_heads")?,
        d_ff: j.get("d_ff").as_usize().context("d_ff")?,
        max_seq: j.get("max_seq").as_usize().context("max_seq")?,
    })
}

fn vec_tensor(v: &[f32]) -> Tensor {
    Tensor::from_vec(v.to_vec(), &[v.len()])
}

fn idx_tensor(v: &[usize]) -> Tensor {
    Tensor::from_vec(v.iter().map(|&i| i as f32).collect(), &[v.len()])
}

fn tensor_idx(t: &Tensor) -> Vec<usize> {
    t.data.iter().map(|&f| f as usize).collect()
}

pub(crate) fn save_model(m: &ModelWeights, path: &Path) -> Result<()> {
    let mut t: BTreeMap<String, Tensor> = BTreeMap::new();
    let mut meta = Json::obj();
    t.insert("embed".into(), m.embed.clone());
    t.insert("pos".into(), m.pos.clone());
    t.insert("final_norm".into(), vec_tensor(&m.final_norm));
    t.insert("unembed".into(), m.unembed.clone());

    let mut layer_kinds = Vec::new();
    for (l, lw) in m.layers.iter().enumerate() {
        let p = format!("layers.{l}");
        t.insert(format!("{p}.attn_norm"), vec_tensor(&lw.attn_norm));
        t.insert(format!("{p}.ffn_norm"), vec_tensor(&lw.ffn_norm));
        t.insert(format!("{p}.attn.wq"), lw.attn.wq.clone());
        t.insert(format!("{p}.attn.wk"), lw.attn.wk.clone());
        t.insert(format!("{p}.attn.wv"), lw.attn.wv.clone());
        t.insert(format!("{p}.attn.wo"), lw.attn.wo.clone());
        match &lw.ffn {
            LayerFfn::Dense(f) => {
                layer_kinds.push("dense".to_string());
                t.insert(format!("{p}.ffn.w_gate"), f.w_gate.clone());
                t.insert(format!("{p}.ffn.w_up"), f.w_up.clone());
                t.insert(format!("{p}.ffn.w_down"), f.w_down.clone());
            }
            LayerFfn::Moe(moe) => {
                layer_kinds.push(moe.spec.to_string());
                t.insert(format!("{p}.shared.w_gate"), moe.shared.w_gate.clone());
                t.insert(format!("{p}.shared.w_up"), moe.shared.w_up.clone());
                t.insert(format!("{p}.shared.w_down"), moe.shared.w_down.clone());
                for (e, ex) in moe.experts.iter().enumerate() {
                    t.insert(format!("{p}.experts.{e}.w_gate"), ex.w_gate.clone());
                    t.insert(format!("{p}.experts.{e}.w_up"), ex.w_up.clone());
                    t.insert(format!("{p}.experts.{e}.w_down"), ex.w_down.clone());
                }
                match &moe.router {
                    Router::Analytical(r) => {
                        t.insert(format!("{p}.router.w_gate_r"), r.w_gate_r.clone());
                        t.insert(format!("{p}.router.w_up_r"), r.w_up_r.clone());
                    }
                    Router::Linear(w) => {
                        t.insert(format!("{p}.router.linear"), w.clone());
                    }
                }
                t.insert(format!("{p}.gate_scale"), vec_tensor(&moe.gate_scale));
                t.insert(format!("{p}.gate_bias"), vec_tensor(&moe.gate_bias));
                t.insert(format!("{p}.shared_neurons"), idx_tensor(&moe.shared_neurons));
                t.insert(format!("{p}.representatives"), idx_tensor(&moe.representatives));
                for (e, idx) in moe.expert_neurons.iter().enumerate() {
                    t.insert(format!("{p}.expert_neurons.{e}"), idx_tensor(idx));
                }
                if let Some(comp) = &moe.compensation {
                    for (e, c) in comp.iter().enumerate() {
                        t.insert(format!("{p}.compensation.{e}"), vec_tensor(c));
                    }
                }
            }
        }
    }
    meta.set("layer_kinds", layer_kinds);
    write_cmw(path, &config_to_json(&m.config), &meta, &t)
}

pub(crate) fn load_model(path: &Path) -> Result<ModelWeights> {
    let file = read_cmw(path)?;
    let config = config_from_json(&file.config)?;
    let t = &file.tensors;
    let get = |name: &str| -> Result<Tensor> {
        t.get(name).cloned().ok_or_else(|| anyhow::anyhow!("missing tensor {name}"))
    };
    let kinds = file.meta.get("layer_kinds");
    let mut layers = Vec::new();
    for l in 0..config.n_layers {
        let p = format!("layers.{l}");
        let kind = kinds
            .as_arr()
            .and_then(|a| a.get(l))
            .and_then(|v| v.as_str())
            .unwrap_or("dense")
            .to_string();
        let ffn = if kind == "dense" {
            LayerFfn::Dense(FfnWeights {
                w_gate: get(&format!("{p}.ffn.w_gate"))?,
                w_up: get(&format!("{p}.ffn.w_up"))?,
                w_down: get(&format!("{p}.ffn.w_down"))?,
            })
        } else {
            let spec: MoeSpec = kind.parse()?;
            let mut experts = Vec::new();
            let mut expert_neurons = Vec::new();
            for e in 0..spec.routed() {
                experts.push(FfnWeights {
                    w_gate: get(&format!("{p}.experts.{e}.w_gate"))?,
                    w_up: get(&format!("{p}.experts.{e}.w_up"))?,
                    w_down: get(&format!("{p}.experts.{e}.w_down"))?,
                });
                expert_neurons.push(tensor_idx(&get(&format!("{p}.expert_neurons.{e}"))?));
            }
            LayerFfn::Moe(MoeLayerWeights {
                spec,
                shared: FfnWeights {
                    w_gate: get(&format!("{p}.shared.w_gate"))?,
                    w_up: get(&format!("{p}.shared.w_up"))?,
                    w_down: get(&format!("{p}.shared.w_down"))?,
                },
                experts,
                router: if t.contains_key(&format!("{p}.router.linear")) {
                    Router::Linear(get(&format!("{p}.router.linear"))?)
                } else {
                    Router::Analytical(RouterWeights {
                        w_gate_r: get(&format!("{p}.router.w_gate_r"))?,
                        w_up_r: get(&format!("{p}.router.w_up_r"))?,
                    })
                },
                gate_scale: get(&format!("{p}.gate_scale"))?.data,
                gate_bias: get(&format!("{p}.gate_bias"))?.data,
                shared_neurons: tensor_idx(&get(&format!("{p}.shared_neurons"))?),
                expert_neurons,
                representatives: tensor_idx(&get(&format!("{p}.representatives"))?),
                compensation: if t.contains_key(&format!("{p}.compensation.0")) {
                    Some(
                        (0..spec.routed())
                            .map(|e| get(&format!("{p}.compensation.{e}")).map(|t| t.data))
                            .collect::<Result<Vec<_>>>()?,
                    )
                } else {
                    None
                },
            })
        };
        layers.push(LayerWeights {
            attn_norm: get(&format!("{p}.attn_norm"))?.data,
            attn: AttnWeights {
                wq: get(&format!("{p}.attn.wq"))?,
                wk: get(&format!("{p}.attn.wk"))?,
                wv: get(&format!("{p}.attn.wv"))?,
                wo: get(&format!("{p}.attn.wo"))?,
            },
            ffn_norm: get(&format!("{p}.ffn_norm"))?.data,
            ffn,
        });
    }
    Ok(ModelWeights {
        config,
        embed: get("embed")?,
        pos: get("pos")?,
        layers,
        final_norm: get("final_norm")?.data,
        unembed: get("unembed")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::model_config;
    use crate::util::Rng;

    #[test]
    fn raw_cmw_roundtrip() {
        let dir = std::env::temp_dir().join("cmoe_test_fmt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("raw.cmw");
        let mut tensors = BTreeMap::new();
        let mut rng = Rng::new(3);
        tensors.insert("a".to_string(), Tensor::randn(&mut rng, &[3, 4], 1.0));
        tensors.insert("b.c".to_string(), Tensor::randn(&mut rng, &[7], 1.0));
        let mut cfg = Json::obj();
        cfg.set("d_model", 16usize);
        write_cmw(&path, &cfg, &Json::Null, &tensors).unwrap();
        let back = read_cmw(&path).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors["a"], tensors["a"]);
        assert_eq!(back.tensors["b.c"], tensors["b.c"]);
        assert_eq!(back.config.get("d_model").as_usize().unwrap(), 16);
    }

    #[test]
    fn dense_model_roundtrip() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(4);
        let m = ModelWeights::random(&cfg, &mut rng);
        let path = std::env::temp_dir().join("cmoe_test_dense.cmw");
        m.save(&path).unwrap();
        let back = ModelWeights::load(&path).unwrap();
        assert_eq!(back.config, m.config);
        assert_eq!(back.embed, m.embed);
        assert_eq!(back.dense_ffn(0).w_gate, m.dense_ffn(0).w_gate);
        assert_eq!(back.layers.len(), m.layers.len());
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("cmoe_test_bad.cmw");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_cmw(&path).is_err());
    }
}
