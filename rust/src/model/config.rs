//! Transformer and MoE configuration types.

use anyhow::{bail, Result};
use std::fmt;
use std::str::FromStr;

/// Architecture hyperparameters of a LLaMA-style decoder-only model
/// (RMSNorm, rotary-free learned positions for simplicity, SwiGLU FFN).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransformerConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// FFN hidden dimension `d_h` (the dimension CMoE partitions).
    pub d_ff: usize,
    /// Maximum sequence length artifacts are compiled for.
    pub max_seq: usize,
}

impl TransformerConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (weights only, tied unembedding not counted).
    pub fn param_count(&self) -> usize {
        let attn = 4 * self.d_model * self.d_model;
        let ffn = 3 * self.d_model * self.d_ff;
        let norms = 2 * self.d_model;
        self.vocab * self.d_model                 // embed
            + self.n_layers * (attn + ffn + norms)
            + self.d_model                        // final norm
            + self.vocab * self.d_model // unembed
            + self.max_seq * self.d_model // learned positions
    }

    /// Analytic FLOPs for one token of dense forward (2·MACs).
    pub fn flops_per_token_dense(&self) -> f64 {
        let attn_proj = 4.0 * (self.d_model * self.d_model) as f64;
        let ffn = 3.0 * (self.d_model * self.d_ff) as f64;
        let logits = (self.d_model * self.vocab) as f64;
        2.0 * (self.n_layers as f64 * (attn_proj + ffn) + logits)
    }
}

/// MoE expert layout written `SxAyEz`: `x` shared experts + `y` active
/// routed experts out of `z` total experts (so `z - x` routed total).
///
/// The paper's default is `S3A3E8` at 25% sparsity: 3 shared + 3-of-5
/// routed active → 6/8 of neurons active per token.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MoeSpec {
    /// Number of shared (always-active) experts `N_s`.
    pub shared: usize,
    /// Number of routed experts activated per token `N_k`.
    pub active: usize,
    /// Total experts `N = N_s + N_r`.
    pub total: usize,
}

impl MoeSpec {
    pub fn new(shared: usize, active: usize, total: usize) -> Result<Self> {
        let spec = MoeSpec { shared, active, total };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        if self.total == 0 {
            bail!("MoeSpec: total experts must be > 0");
        }
        if self.shared >= self.total {
            bail!("MoeSpec: shared ({}) must be < total ({})", self.shared, self.total);
        }
        if self.active > self.routed() {
            bail!(
                "MoeSpec: active ({}) exceeds routed experts ({})",
                self.active,
                self.routed()
            );
        }
        Ok(())
    }

    /// Number of routed experts `N_r = N - N_s`.
    pub fn routed(&self) -> usize {
        self.total - self.shared
    }

    /// Fraction of FFN neurons *inactive* per token — the paper's
    /// "sparsity" (e.g. S3A3E8 → 1 - 6/8 = 25%).
    pub fn sparsity(&self) -> f64 {
        1.0 - (self.shared + self.active) as f64 / self.total as f64
    }

    /// Expert size `m = d_h / N`; errors if `N ∤ d_h`.
    pub fn expert_size(&self, d_ff: usize) -> Result<usize> {
        if d_ff % self.total != 0 {
            bail!("expert count {} does not divide d_ff {}", self.total, d_ff);
        }
        Ok(d_ff / self.total)
    }

    /// FFN FLOPs multiplier vs dense (active fraction of neurons, plus
    /// the router's own `2·d·N_r` MACs folded in by the caller).
    pub fn active_fraction(&self) -> f64 {
        (self.shared + self.active) as f64 / self.total as f64
    }
}

impl FromStr for MoeSpec {
    type Err = anyhow::Error;

    /// Parse `"S3A3E8"` (case-insensitive).
    fn from_str(s: &str) -> Result<Self> {
        let up = s.to_ascii_uppercase();
        let bytes = up.as_bytes();
        if bytes.first() != Some(&b'S') {
            bail!("MoeSpec must start with 'S': {s}");
        }
        let a_pos = up.find('A').ok_or_else(|| anyhow::anyhow!("MoeSpec missing 'A': {s}"))?;
        let e_pos = up.find('E').ok_or_else(|| anyhow::anyhow!("MoeSpec missing 'E': {s}"))?;
        if !(1 < a_pos && a_pos < e_pos) {
            bail!("malformed MoeSpec: {s}");
        }
        let shared: usize = up[1..a_pos].parse()?;
        let active: usize = up[a_pos + 1..e_pos].parse()?;
        let total: usize = up[e_pos + 1..].parse()?;
        MoeSpec::new(shared, active, total)
    }
}

impl fmt::Display for MoeSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "S{}A{}E{}", self.shared, self.active, self.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for s in ["S3A3E8", "S1A5E8", "S6A6E16", "S3A9E16", "S2A4E8", "S4A8E16"] {
            let spec: MoeSpec = s.parse().unwrap();
            assert_eq!(spec.to_string(), s);
        }
    }

    #[test]
    fn paper_default_sparsity() {
        let spec: MoeSpec = "S3A3E8".parse().unwrap();
        assert_eq!(spec.routed(), 5);
        assert!((spec.sparsity() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn table9_configs_all_25pct() {
        for s in ["S1A5E8", "S3A3E8", "S2A4E8", "S4A8E16", "S6A6E16", "S3A9E16"] {
            let spec: MoeSpec = s.parse().unwrap();
            assert!((spec.sparsity() - 0.25).abs() < 1e-12, "{s}: {}", spec.sparsity());
        }
    }

    #[test]
    fn invalid_specs_rejected() {
        assert!("S8A1E8".parse::<MoeSpec>().is_err()); // shared == total
        assert!("S3A6E8".parse::<MoeSpec>().is_err()); // active > routed
        assert!("A3E8".parse::<MoeSpec>().is_err());
        assert!("S3E8".parse::<MoeSpec>().is_err());
        assert!("garbage".parse::<MoeSpec>().is_err());
    }

    #[test]
    fn expert_size_divides() {
        let spec: MoeSpec = "S3A3E8".parse().unwrap();
        assert_eq!(spec.expert_size(1024).unwrap(), 128);
        assert!(spec.expert_size(1001).is_err());
    }

    #[test]
    fn config_param_count_sane() {
        let cfg = TransformerConfig {
            name: "t".into(),
            vocab: 256,
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_ff: 512,
            max_seq: 128,
        };
        // embed 32768 + pos 16384 + layers 2*(65536 + 196608 + 256) + final 128 + unembed 32768
        assert_eq!(cfg.param_count(), 256 * 128 + 128 * 128 + 2 * (4 * 128 * 128 + 3 * 128 * 512 + 256) + 128 + 256 * 128);
        assert_eq!(cfg.head_dim(), 32);
        assert!(cfg.flops_per_token_dense() > 0.0);
    }
}
