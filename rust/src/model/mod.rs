//! Model definitions: transformer configuration, the `SxAyEz` MoE
//! specification grammar, weight containers, and the `.cmw` on-disk
//! weight format shared with the python build path.

mod config;
mod weights;
mod format;
mod zoo;

pub use config::{MoeSpec, TransformerConfig};
pub use weights::{
    AttnWeights, FfnWeights, LayerFfn, LayerWeights, ModelWeights, MoeLayerWeights, Router,
    RouterWeights,
};
pub use format::{read_cmw, write_cmw, CmwFile};
pub use zoo::{model_config, MODEL_ZOO};
