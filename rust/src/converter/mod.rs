//! The CMoE conversion pipeline (§4): analytical FFN → MoE
//! restructuring.
//!
//! Stages per layer (timed in [`ConvertReport`]):
//! 1. **Shared-expert selection** — the `N_s·m` highest activation-rate
//!    neurons become one fused always-active expert (Eq. 16).
//! 2. **Routed-expert construction** — remaining neurons are balanced-
//!    clustered on their binary activation columns (Hamming distance)
//!    with centroids initialized from the highest-rate remaining
//!    neurons (§A.3).
//! 3. **Analytical router** — per cluster, the representative neuron
//!    closest to the centroid (Eq. 25); the router is the SwiGLU
//!    response of those `N_r` columns (Eq. 8). No training.
//! 4. **Weight slicing** — experts are views (copies) of the original
//!    matrices; conversion is a *permutation* of neurons, verified by
//!    tests and a debug assertion.
//!
//! [`hierarchical`] applies the same restructuring to each routed expert
//! of an existing MoE layer (§4.4).

mod hierarchical;

pub use hierarchical::{hierarchical_convert, hier_moe_forward, HierMoeLayer};

use crate::clustering;
use crate::model::{
    FfnWeights, LayerFfn, ModelWeights, MoeLayerWeights, MoeSpec, Router, RouterWeights,
};
use crate::profiling::ActivationProfile;
use crate::util::Timer;
use anyhow::{bail, Context, Result};
use std::time::Duration;

/// Conversion options.
#[derive(Clone, Debug)]
pub struct ConvertOptions {
    /// Balanced K-means iteration cap (assignment is exact each iter).
    pub max_kmeans_iters: usize,
    /// Use the exact JV balanced assignment (true, default) or the
    /// greedy approximation (ablation).
    pub exact_assignment: bool,
}

impl Default for ConvertOptions {
    fn default() -> Self {
        ConvertOptions { max_kmeans_iters: 8, exact_assignment: true }
    }
}

/// Per-stage wall-clock of a conversion (Table 6's "Construct time").
#[derive(Clone, Debug, Default)]
pub struct ConvertReport {
    pub shared_select: Duration,
    pub clustering: Duration,
    pub router: Duration,
    pub slicing: Duration,
    pub total: Duration,
    pub layers: usize,
}

impl ConvertReport {
    fn accumulate(&mut self, other: &ConvertReport) {
        self.shared_select += other.shared_select;
        self.clustering += other.clustering;
        self.router += other.router;
        self.slicing += other.slicing;
        self.total += other.total;
        self.layers += other.layers;
    }
}

/// A fully converted model plus its report.
pub struct ConvertedModel {
    pub model: ModelWeights,
    pub report: ConvertReport,
}

/// Convert a single dense FFN into a CMoE layer.
pub fn convert_ffn(
    ffn: &FfnWeights,
    profile: &ActivationProfile,
    spec: &MoeSpec,
    opts: &ConvertOptions,
) -> Result<MoeLayerWeights> {
    let (moe, _report) = convert_ffn_timed(ffn, profile, spec, opts)?;
    Ok(moe)
}

/// Convert with per-stage timings.
pub fn convert_ffn_timed(
    ffn: &FfnWeights,
    profile: &ActivationProfile,
    spec: &MoeSpec,
    opts: &ConvertOptions,
) -> Result<(MoeLayerWeights, ConvertReport)> {
    spec.validate()?;
    let d_h = ffn.hidden_dim();
    if profile.d_h != d_h {
        bail!("profile d_h {} != ffn d_h {}", profile.d_h, d_h);
    }
    let m = spec.expert_size(d_h)?;
    let n_r = spec.routed();
    let mut report = ConvertReport { layers: 1, ..Default::default() };
    let mut timer = Timer::start();

    // ---- Stage 1: shared experts (Eq. 16) -------------------------------
    let shared_neurons = profile.top_rate_neurons(spec.shared * m);
    let shared_set: std::collections::HashSet<usize> = shared_neurons.iter().copied().collect();
    let remaining: Vec<usize> = (0..d_h).filter(|i| !shared_set.contains(i)).collect();
    debug_assert_eq!(remaining.len(), n_r * m);
    report.shared_select = timer.lap();

    // ---- Stage 2: balanced clustering of routed neurons (§A.3) ----------
    let points = profile.columns_tensor(&remaining);
    // centroid init: highest-rate remaining neurons
    let mu = profile.rates();
    let mut by_rate: Vec<usize> = (0..remaining.len()).collect();
    by_rate.sort_by(|&a, &b| {
        mu[remaining[b]].partial_cmp(&mu[remaining[a]]).unwrap().then(remaining[a].cmp(&remaining[b]))
    });
    let init: Vec<usize> = by_rate[..n_r].to_vec();
    let cl = if opts.exact_assignment {
        clustering::balanced_kmeans(&points, n_r, &init, opts.max_kmeans_iters)
    } else {
        let mut c = clustering::balanced_kmeans(&points, n_r, &init, 1);
        // greedy ablation: one LAP round then greedy rebalance of Lloyd
        clustering::rebalance(&points, &mut c, n_r);
        c
    };
    let members = cl.members(n_r);
    report.clustering = timer.lap();

    // ---- Stage 3: representative neurons + analytical router (Eq. 25/8) -
    let mut representatives = Vec::with_capacity(n_r);
    for (j, mem) in members.iter().enumerate() {
        let centroid = cl.centroids.row(j);
        let mut best = mem[0];
        let mut best_d = f64::INFINITY;
        for &p in mem {
            let col = points.row(p);
            let d: f64 = col
                .iter()
                .zip(centroid)
                .map(|(a, b)| ((a - b) as f64) * ((a - b) as f64))
                .sum();
            if d < best_d {
                best_d = d;
                best = p;
            }
        }
        representatives.push(remaining[best]);
    }
    let router = Router::Analytical(RouterWeights {
        w_gate_r: ffn.w_gate.select_cols(&representatives),
        w_up_r: ffn.w_up.select_cols(&representatives),
    });
    report.router = timer.lap();

    // ---- Stage 4: weight slicing ----------------------------------------
    let shared = ffn.slice_neurons(&shared_neurons);
    let mut experts = Vec::with_capacity(n_r);
    let mut expert_neurons = Vec::with_capacity(n_r);
    for mem in &members {
        let orig: Vec<usize> = mem.iter().map(|&p| remaining[p]).collect();
        experts.push(ffn.slice_neurons(&orig));
        expert_neurons.push(orig);
    }
    report.slicing = timer.lap();
    report.total = report.shared_select + report.clustering + report.router + report.slicing;

    let moe = MoeLayerWeights {
        spec: *spec,
        shared,
        experts,
        router,
        gate_scale: vec![0.0; n_r],
        gate_bias: vec![0.0; n_r],
        shared_neurons,
        expert_neurons,
        representatives,
        compensation: None,
    };
    debug_assert_eq!(moe.covered_neurons(), (0..d_h).collect::<Vec<_>>(), "not a permutation");
    Ok((moe, report))
}

/// Convert every dense FFN layer of a model. `profiles[l]` must hold the
/// calibration profile of layer `l`.
pub fn convert_model(
    model: &ModelWeights,
    profiles: &[ActivationProfile],
    spec: &MoeSpec,
    opts: &ConvertOptions,
) -> Result<ConvertedModel> {
    if profiles.len() != model.config.n_layers {
        bail!("need one profile per layer ({} != {})", profiles.len(), model.config.n_layers);
    }
    let mut out = model.clone();
    let mut report = ConvertReport::default();
    for (l, layer) in out.layers.iter_mut().enumerate() {
        let ffn = match &layer.ffn {
            LayerFfn::Dense(f) => f,
            LayerFfn::Moe(_) => bail!("layer {l} is already MoE; use hierarchical_convert"),
        };
        let (moe, r) = convert_ffn_timed(ffn, &profiles[l], spec, opts)
            .with_context(|| format!("layer {l}"))?;
        report.accumulate(&r);
        layer.ffn = LayerFfn::Moe(moe);
    }
    Ok(ConvertedModel { model: out, report })
}

/// Expected reconstruction error `E‖F_MoE(x) − F(x)‖ / E‖F(x)‖` on a
/// probe batch — the conversion-quality metric used by Table 5-style
/// ablations (lower is better).
pub fn reconstruction_error(
    ffn: &FfnWeights,
    moe: &MoeLayerWeights,
    probe: &crate::tensor::Tensor,
) -> f64 {
    let dense = crate::tensor::swiglu_ffn(probe, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
    let (sparse, _) = crate::moe::moe_ffn_forward(moe, probe);
    let mut diff = dense.clone();
    for (a, b) in diff.data.iter_mut().zip(&sparse.data) {
        *a -= b;
    }
    (diff.norm() / dense.norm().max(1e-12)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{swiglu_hidden, Tensor};
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    /// Random FFN + profile with planted structure: `hot` neurons always
    /// fire; the rest fire in `n_groups` correlated groups.
    fn planted(
        rng: &mut Rng,
        d: usize,
        d_h: usize,
        n_hot: usize,
        n_groups: usize,
        q: usize,
    ) -> (FfnWeights, ActivationProfile, Vec<usize>, Vec<usize>) {
        let ffn = FfnWeights {
            w_gate: Tensor::randn(rng, &[d, d_h], 0.4),
            w_up: Tensor::randn(rng, &[d, d_h], 0.4),
            w_down: Tensor::randn(rng, &[d_h, d], 0.4),
        };
        // choose hot neurons + group labels for the rest
        let mut ids: Vec<usize> = (0..d_h).collect();
        rng.shuffle(&mut ids);
        let hot: Vec<usize> = ids[..n_hot].to_vec();
        let rest: Vec<usize> = ids[n_hot..].to_vec();
        let mut group_of = vec![usize::MAX; d_h];
        for (k, &i) in rest.iter().enumerate() {
            group_of[i] = k % n_groups;
        }
        // synthesize hidden states: hot always large, one group active
        // per token
        let mut h = Tensor::zeros(&[q, d_h]);
        for t in 0..q {
            let g = rng.below(n_groups);
            let row = h.row_mut(t);
            for i in 0..d_h {
                row[i] = 0.01 * rng.normal();
            }
            for &i in &hot {
                row[i] = 3.0 + 0.1 * rng.normal();
            }
            for i in 0..d_h {
                if group_of[i] == g {
                    row[i] = 1.5 + 0.1 * rng.normal();
                }
            }
        }
        let k_a = n_hot + (d_h - n_hot) / n_groups;
        let prof = ActivationProfile::from_hidden(&h, k_a);
        (ffn, prof, hot, group_of)
    }

    #[test]
    fn conversion_is_a_permutation() {
        let mut rng = Rng::new(31);
        let (ffn, prof, _, _) = planted(&mut rng, 8, 64, 16, 6, 150);
        let spec: MoeSpec = "S2A3E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        assert_eq!(moe.covered_neurons(), (0..64).collect::<Vec<_>>());
        assert_eq!(moe.experts.len(), 6);
        for e in &moe.experts {
            assert_eq!(e.hidden_dim(), 8);
        }
        assert_eq!(moe.shared.hidden_dim(), 16);
    }

    #[test]
    fn shared_expert_captures_hot_neurons() {
        let mut rng = Rng::new(32);
        let (ffn, prof, hot, _) = planted(&mut rng, 8, 64, 16, 6, 200);
        let spec: MoeSpec = "S2A3E8".parse().unwrap(); // 2*8=16 shared slots
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        let shared: std::collections::HashSet<_> = moe.shared_neurons.iter().copied().collect();
        let captured = hot.iter().filter(|i| shared.contains(i)).count();
        assert!(captured >= 15, "only {captured}/16 hot neurons in shared expert");
    }

    #[test]
    fn clustering_recovers_planted_groups() {
        let mut rng = Rng::new(33);
        // 64 neurons: 16 hot, 48 in 6 groups of 8 → exactly E8 S2 layout
        let (ffn, prof, _, group_of) = planted(&mut rng, 8, 64, 16, 6, 300);
        let spec: MoeSpec = "S2A3E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        // each routed expert should be dominated by one planted group
        let mut pure = 0;
        for mem in &moe.expert_neurons {
            let mut counts = std::collections::HashMap::new();
            for &i in mem {
                *counts.entry(group_of[i]).or_insert(0usize) += 1;
            }
            let maj = counts.values().copied().max().unwrap();
            if maj as f64 >= 0.75 * mem.len() as f64 {
                pure += 1;
            }
        }
        assert!(pure >= 5, "only {pure}/6 experts are group-pure");
    }

    #[test]
    fn representatives_belong_to_their_expert() {
        let mut rng = Rng::new(34);
        let (ffn, prof, _, _) = planted(&mut rng, 8, 64, 16, 6, 150);
        let spec: MoeSpec = "S2A3E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        for (j, &r) in moe.representatives.iter().enumerate() {
            assert!(moe.expert_neurons[j].contains(&r), "rep {r} not in expert {j}");
        }
        // router columns match the representative neurons' weights
        let Router::Analytical(rw) = &moe.router else { panic!("expected analytical router") };
        for (j, &r) in moe.representatives.iter().enumerate() {
            for row in 0..8 {
                assert_eq!(rw.w_gate_r.at2(row, j), ffn.w_gate.at2(row, r));
                assert_eq!(rw.w_up_r.at2(row, j), ffn.w_up.at2(row, r));
            }
        }
    }

    #[test]
    fn expert_weights_match_original_columns() {
        let mut rng = Rng::new(35);
        let (ffn, prof, _, _) = planted(&mut rng, 8, 64, 16, 6, 100);
        let spec: MoeSpec = "S2A3E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        for (e, neurons) in moe.expert_neurons.iter().enumerate() {
            for (slot, &orig) in neurons.iter().enumerate() {
                for row in 0..8 {
                    assert_eq!(moe.experts[e].w_gate.at2(row, slot), ffn.w_gate.at2(row, orig));
                }
                assert_eq!(moe.experts[e].w_down.row(slot), ffn.w_down.row(orig));
            }
        }
    }

    #[test]
    fn router_ranks_active_group_highest() {
        // On a token where group g fires, the router's top choice should
        // be the expert holding group g (scores approximate expert
        // hidden-state magnitude, §4.2).
        let mut rng = Rng::new(36);
        let (ffn, prof, _, group_of) = planted(&mut rng, 8, 64, 16, 6, 300);
        let spec: MoeSpec = "S2A1E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        // map expert -> dominant planted group
        let dominant: Vec<usize> = moe
            .expert_neurons
            .iter()
            .map(|mem| {
                let mut counts = std::collections::HashMap::new();
                for &i in mem {
                    *counts.entry(group_of[i]).or_insert(0usize) += 1;
                }
                *counts.iter().max_by_key(|(_, &c)| c).unwrap().0
            })
            .collect();
        // build probe tokens that light up a known group: reuse the
        // planted generator's structure by sampling x and measuring which
        // group's neurons have max hidden response
        let x = Tensor::randn(&mut rng, &[64, 8], 1.0);
        let h = swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let dec = crate::moe::route_tokens(&moe, &x);
        let mut hits = 0;
        for t in 0..64 {
            // which expert has the largest true hidden L1?
            let mut best_e = 0;
            let mut best_l1 = -1.0f32;
            for (e, mem) in moe.expert_neurons.iter().enumerate() {
                let l1: f32 = mem.iter().map(|&i| h.at2(t, i).abs()).sum();
                if l1 > best_l1 {
                    best_l1 = l1;
                    best_e = e;
                }
            }
            if dec[t].experts[0] == best_e {
                hits += 1;
            }
        }
        let _ = dominant;
        // The analytical router scores through ONE representative neuron
        // per expert, so on unstructured gaussian probes it is a noisy
        // proxy — the paper's claim is "well above chance", not exact
        // agreement (chance here = 1/6 ≈ 10.7/64).
        assert!(hits >= 14, "router matched true-best expert only {hits}/64 times");
    }

    #[test]
    fn sparsity_monotonically_hurts_reconstruction() {
        let mut rng = Rng::new(37);
        let (ffn, prof, _, _) = planted(&mut rng, 8, 64, 16, 6, 200);
        let probe = Tensor::randn(&mut rng, &[64, 8], 1.0);
        let mut last = -1.0;
        for spec_s in ["S2A6E8", "S2A4E8", "S2A2E8"] {
            let spec: MoeSpec = spec_s.parse().unwrap();
            let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
            let err = reconstruction_error(&ffn, &moe, &probe);
            assert!(err >= last, "error not monotone at {spec_s}: {err} < {last}");
            last = err;
        }
    }

    #[test]
    fn convert_model_all_layers() {
        let mut rng = Rng::new(38);
        let cfg = crate::model::model_config("tiny").unwrap();
        let model = ModelWeights::random(&cfg, &mut rng);
        let x = Tensor::randn(&mut rng, &[80, cfg.d_model], 1.0);
        let profiles: Vec<ActivationProfile> = (0..cfg.n_layers)
            .map(|l| {
                let f = model.dense_ffn(l);
                let h = swiglu_hidden(&x, &f.w_gate, &f.w_up);
                ActivationProfile::from_hidden(&h, 16)
            })
            .collect();
        let spec: MoeSpec = "S3A3E8".parse().unwrap();
        let conv = convert_model(&model, &profiles, &spec, &ConvertOptions::default()).unwrap();
        assert_eq!(conv.report.layers, cfg.n_layers);
        assert!(conv.report.total.as_nanos() > 0);
        for l in &conv.model.layers {
            assert!(matches!(l.ffn, LayerFfn::Moe(_)));
        }
        // double conversion must fail
        assert!(convert_model(&conv.model, &profiles, &spec, &ConvertOptions::default()).is_err());
    }

    #[test]
    fn conversion_property_always_partitions() {
        check("convert-partition", Config { cases: 16, max_size: 4, ..Default::default() }, |rng, size| {
            let d = 4 + size;
            let n = [8usize, 16][rng.below(2)];
            let m = [2usize, 4][rng.below(2)];
            let d_h = n * m;
            let ffn = FfnWeights {
                w_gate: Tensor::randn(rng, &[d, d_h], 0.5),
                w_up: Tensor::randn(rng, &[d, d_h], 0.5),
                w_down: Tensor::randn(rng, &[d_h, d], 0.5),
            };
            let x = Tensor::randn(rng, &[40, d], 1.0);
            let h = swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
            let prof = ActivationProfile::from_hidden(&h, (d_h / 4).max(1));
            let shared = rng.range(1, n - 1);
            let routed = n - shared;
            let active = rng.range(1, routed + 1);
            let spec = MoeSpec::new(shared, active, n).unwrap();
            let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default())
                .map_err(|e| e.to_string())?;
            crate::prop_assert!(
                moe.covered_neurons() == (0..d_h).collect::<Vec<_>>(),
                "neurons lost/duplicated for {spec}"
            );
            crate::prop_assert!(moe.experts.len() == routed, "wrong expert count");
            crate::prop_assert!(
                moe.experts.iter().all(|e| e.hidden_dim() == m),
                "unbalanced expert sizes"
            );
            Ok(())
        });
    }
}
