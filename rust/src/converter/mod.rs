//! The CMoE conversion math (§4): analytical FFN → MoE restructuring.
//!
//! Stages per layer (timed in [`ConvertReport`]):
//! 1. **Shared-expert selection** — the `N_s·m` highest activation-rate
//!    neurons become one fused always-active expert (Eq. 16).
//! 2. **Routed-expert construction** — remaining neurons are balanced-
//!    clustered on their binary activation columns (Hamming distance)
//!    with centroids initialized from the highest-rate remaining
//!    neurons (§A.3).
//! 3. **Analytical router** — per cluster, the representative neuron
//!    closest to the centroid (Eq. 25); the router is the SwiGLU
//!    response of those `N_r` columns (Eq. 8). No training.
//! 4. **Weight slicing** — experts are views (copies) of the original
//!    matrices; conversion is a *permutation* of neurons, verified by
//!    tests and a debug assertion.
//!
//! The stages are exposed individually — [`cmoe_layer_partition`]
//! (1+2+3a), [`analytical_router`] (3b) and [`assemble_moe_layer`] (4) —
//! so [`crate::pipeline`] can compose them with baseline partitioners
//! and routers behind one staged, resumable API; [`convert_ffn_timed`]
//! is the fused single-call form and goes through the exact same code.
//! The serializable boundary types are [`LayerPartition`] (partition →
//! router) and [`RouterBuild`] (router → assembly).
//!
//! [`hierarchical`] applies the same restructuring to each routed expert
//! of an existing MoE layer (§4.4).

mod hierarchical;

pub use hierarchical::{hierarchical_convert, hier_moe_forward, HierMoeLayer};

use crate::clustering;
use crate::model::{
    FfnWeights, LayerFfn, ModelWeights, MoeLayerWeights, MoeSpec, Router, RouterWeights,
};
use crate::profiling::ActivationProfile;
use crate::util::Timer;
use anyhow::{bail, Context, Result};
use std::time::Duration;

/// Conversion options.
#[derive(Clone, Debug)]
pub struct ConvertOptions {
    /// Balanced K-means iteration cap (assignment is exact each iter).
    pub max_kmeans_iters: usize,
    /// Use the exact JV balanced assignment (true, default) or the
    /// greedy approximation (ablation).
    pub exact_assignment: bool,
}

impl Default for ConvertOptions {
    fn default() -> Self {
        ConvertOptions { max_kmeans_iters: 8, exact_assignment: true }
    }
}

/// Per-stage wall-clock of a conversion (Table 6's "Construct time").
#[derive(Clone, Debug, Default)]
pub struct ConvertReport {
    pub shared_select: Duration,
    pub clustering: Duration,
    pub router: Duration,
    pub slicing: Duration,
    pub total: Duration,
    pub layers: usize,
}

impl ConvertReport {
    fn accumulate(&mut self, other: &ConvertReport) {
        self.shared_select += other.shared_select;
        self.clustering += other.clustering;
        self.router += other.router;
        self.slicing += other.slicing;
        self.total += other.total;
        self.layers += other.layers;
    }
}

/// A fully converted model plus its report.
pub struct ConvertedModel {
    pub model: ModelWeights,
    pub report: ConvertReport,
}

/// Neuron membership produced by a partition stage — the serializable
/// boundary between partitioning and router construction (JSON codec in
/// [`crate::pipeline::artifact`]). Baselines emit it with empty
/// `shared_neurons`; CMoE additionally records the representative
/// neuron it read off the clustering state.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPartition {
    pub spec: MoeSpec,
    /// Original-FFN indices of the fused shared expert's neurons.
    pub shared_neurons: Vec<usize>,
    /// Original-FFN indices per routed expert.
    pub expert_neurons: Vec<Vec<usize>>,
    /// Representative neuron per routed expert when the partitioner
    /// already picked one; `None` leaves the Eq. 25 search to the
    /// router stage ([`representative_neurons`]).
    pub representatives: Option<Vec<usize>>,
}

impl LayerPartition {
    /// Check the partition is an exact permutation of `0..d_h` with
    /// `spec.routed()` balanced experts of `d_h / spec.total` neurons
    /// (and `spec.shared` experts' worth of shared neurons).
    pub fn validate(&self, d_h: usize) -> Result<()> {
        let m = self.spec.expert_size(d_h)?;
        if self.shared_neurons.len() != self.spec.shared * m {
            bail!(
                "shared slice holds {} neurons, spec {} wants {}",
                self.shared_neurons.len(),
                self.spec,
                self.spec.shared * m
            );
        }
        if self.expert_neurons.len() != self.spec.routed() {
            bail!(
                "{} routed experts, spec {} wants {}",
                self.expert_neurons.len(),
                self.spec,
                self.spec.routed()
            );
        }
        for (e, mem) in self.expert_neurons.iter().enumerate() {
            if mem.len() != m {
                bail!("expert {e} holds {} neurons, expected {m}", mem.len());
            }
        }
        let mut all: Vec<usize> = self
            .shared_neurons
            .iter()
            .chain(self.expert_neurons.iter().flatten())
            .copied()
            .collect();
        all.sort_unstable();
        if all != (0..d_h).collect::<Vec<_>>() {
            bail!("partition is not a permutation of 0..{d_h}");
        }
        if let Some(reps) = &self.representatives {
            if reps.len() != self.spec.routed() {
                bail!("{} representatives for {} experts", reps.len(), self.spec.routed());
            }
            for (e, r) in reps.iter().enumerate() {
                if !self.expert_neurons[e].contains(r) {
                    bail!("representative {r} is not a member of expert {e}");
                }
            }
        }
        Ok(())
    }
}

/// Per-stage wall-clock of [`cmoe_layer_partition`].
#[derive(Clone, Debug, Default)]
pub struct PartitionTimings {
    pub shared_select: Duration,
    pub clustering: Duration,
    /// The Eq. 25 representative search (folded into
    /// [`ConvertReport::router`] by [`convert_ffn_timed`]).
    pub representative: Duration,
}

/// Router-stage output consumed by [`assemble_moe_layer`].
#[derive(Clone, Debug)]
pub struct RouterBuild {
    pub router: Router,
    /// Representative neurons backing an analytical router (empty for
    /// trained / global routers, matching the baselines' bookkeeping).
    pub representatives: Vec<usize>,
    /// G-MoEfication-style calibration-mean compensation, when the
    /// method uses it.
    pub compensation: Option<Vec<Vec<f32>>>,
}

/// Stages 1–3a of the CMoE conversion: shared-expert selection (Eq. 16),
/// balanced activation clustering (§A.3), and the representative search
/// against the clustering centroids (Eq. 25). Pure function of the
/// profile — weights are not touched until [`assemble_moe_layer`].
pub fn cmoe_layer_partition(
    profile: &ActivationProfile,
    spec: &MoeSpec,
    opts: &ConvertOptions,
) -> Result<(LayerPartition, PartitionTimings)> {
    spec.validate()?;
    let d_h = profile.d_h;
    let m = spec.expert_size(d_h)?;
    let n_r = spec.routed();
    let mut timings = PartitionTimings::default();
    let mut timer = Timer::start();

    // ---- Stage 1: shared experts (Eq. 16) -------------------------------
    let shared_neurons = profile.top_rate_neurons(spec.shared * m);
    let shared_set: std::collections::HashSet<usize> = shared_neurons.iter().copied().collect();
    let remaining: Vec<usize> = (0..d_h).filter(|i| !shared_set.contains(i)).collect();
    debug_assert_eq!(remaining.len(), n_r * m);
    timings.shared_select = timer.lap();

    // ---- Stage 2: balanced clustering of routed neurons (§A.3) ----------
    let points = profile.columns_tensor(&remaining);
    // centroid init: highest-rate remaining neurons
    let mu = profile.rates();
    let mut by_rate: Vec<usize> = (0..remaining.len()).collect();
    by_rate.sort_by(|&a, &b| {
        mu[remaining[b]].partial_cmp(&mu[remaining[a]]).unwrap().then(remaining[a].cmp(&remaining[b]))
    });
    let init: Vec<usize> = by_rate[..n_r].to_vec();
    let cl = if opts.exact_assignment {
        clustering::balanced_kmeans(&points, n_r, &init, opts.max_kmeans_iters)
    } else {
        let mut c = clustering::balanced_kmeans(&points, n_r, &init, 1);
        // greedy ablation: one LAP round then greedy rebalance of Lloyd
        clustering::rebalance(&points, &mut c, n_r);
        c
    };
    let members = cl.members(n_r);
    timings.clustering = timer.lap();

    // ---- Stage 3a: representative neurons (Eq. 25) ----------------------
    let mut representatives = Vec::with_capacity(n_r);
    for (j, mem) in members.iter().enumerate() {
        let centroid = cl.centroids.row(j);
        let mut best = mem[0];
        let mut best_d = f64::INFINITY;
        for &p in mem {
            let col = points.row(p);
            let d: f64 = col
                .iter()
                .zip(centroid)
                .map(|(a, b)| ((a - b) as f64) * ((a - b) as f64))
                .sum();
            if d < best_d {
                best_d = d;
                best = p;
            }
        }
        representatives.push(remaining[best]);
    }
    let expert_neurons: Vec<Vec<usize>> =
        members.iter().map(|mem| mem.iter().map(|&p| remaining[p]).collect()).collect();
    timings.representative = timer.lap();

    Ok((
        LayerPartition {
            spec: *spec,
            shared_neurons,
            expert_neurons,
            representatives: Some(representatives),
        },
        timings,
    ))
}

/// Eq. 25 for an *arbitrary* partition: per expert, the activation
/// column centroid (member mean) and its nearest member neuron. Shared
/// by the pipeline's analytical [`crate::pipeline::RouterBuilder`] and
/// [`crate::baselines::with_analytical_router`] (the Table 5 "+ ours"
/// hybrids). CMoE's own path reads representatives off the clustering
/// state in [`cmoe_layer_partition`] instead.
pub fn representative_neurons(
    profile: &ActivationProfile,
    expert_neurons: &[Vec<usize>],
) -> Vec<usize> {
    let mut representatives = Vec::with_capacity(expert_neurons.len());
    for mem in expert_neurons {
        // centroid of the expert's activation columns
        let pts = profile.columns_tensor(mem);
        let q = pts.shape[1];
        let mut centroid = vec![0.0f32; q];
        for r in 0..pts.shape[0] {
            for (c, v) in centroid.iter_mut().zip(pts.row(r)) {
                *c += v;
            }
        }
        for c in centroid.iter_mut() {
            *c /= pts.shape[0] as f32;
        }
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for r in 0..pts.shape[0] {
            let d: f64 = pts
                .row(r)
                .iter()
                .zip(&centroid)
                .map(|(a, b)| ((a - b) as f64) * ((a - b) as f64))
                .sum();
            if d < best_d {
                best_d = d;
                best = r;
            }
        }
        representatives.push(mem[best]);
    }
    representatives
}

/// Stage 3b: the analytical router — the SwiGLU response of the
/// representative neurons' weight columns (Eq. 8). No training.
pub fn analytical_router(ffn: &FfnWeights, representatives: &[usize]) -> Router {
    Router::Analytical(RouterWeights {
        w_gate_r: ffn.w_gate.select_cols(representatives),
        w_up_r: ffn.w_up.select_cols(representatives),
    })
}

/// Stage 4: slice the original weights per the partition and attach the
/// router. The single place [`MoeLayerWeights`] are built — CMoE,
/// every baseline and the pipeline all assemble here, so the layer
/// invariants (gate init, neuron bookkeeping) cannot drift apart.
pub fn assemble_moe_layer(
    ffn: &FfnWeights,
    part: &LayerPartition,
    build: RouterBuild,
) -> MoeLayerWeights {
    let n_r = part.expert_neurons.len();
    MoeLayerWeights {
        spec: part.spec,
        shared: ffn.slice_neurons(&part.shared_neurons),
        experts: part.expert_neurons.iter().map(|idx| ffn.slice_neurons(idx)).collect(),
        router: build.router,
        gate_scale: vec![0.0; n_r],
        gate_bias: vec![0.0; n_r],
        shared_neurons: part.shared_neurons.clone(),
        expert_neurons: part.expert_neurons.clone(),
        representatives: build.representatives,
        compensation: build.compensation,
    }
}

/// Convert a single dense FFN into a CMoE layer.
pub fn convert_ffn(
    ffn: &FfnWeights,
    profile: &ActivationProfile,
    spec: &MoeSpec,
    opts: &ConvertOptions,
) -> Result<MoeLayerWeights> {
    let (moe, _report) = convert_ffn_timed(ffn, profile, spec, opts)?;
    Ok(moe)
}

/// Convert with per-stage timings. Composes the staged functions above;
/// the pipeline's `cmoe` method runs the identical code, which is what
/// the golden equivalence test (`tests/pipeline_golden.rs`) pins down.
pub fn convert_ffn_timed(
    ffn: &FfnWeights,
    profile: &ActivationProfile,
    spec: &MoeSpec,
    opts: &ConvertOptions,
) -> Result<(MoeLayerWeights, ConvertReport)> {
    let d_h = ffn.hidden_dim();
    if profile.d_h != d_h {
        bail!("profile d_h {} != ffn d_h {}", profile.d_h, d_h);
    }
    let (part, timings) = cmoe_layer_partition(profile, spec, opts)?;
    let mut timer = Timer::start();
    let representatives =
        part.representatives.clone().expect("cmoe partitioning always picks representatives");
    let router = analytical_router(ffn, &representatives);
    let router_build = timer.lap();
    let moe = assemble_moe_layer(
        ffn,
        &part,
        RouterBuild { router, representatives, compensation: None },
    );
    let slicing = timer.lap();

    let mut report = ConvertReport {
        layers: 1,
        shared_select: timings.shared_select,
        clustering: timings.clustering,
        router: timings.representative + router_build,
        slicing,
        ..Default::default()
    };
    report.total = report.shared_select + report.clustering + report.router + report.slicing;
    debug_assert_eq!(moe.covered_neurons(), (0..d_h).collect::<Vec<_>>(), "not a permutation");
    Ok((moe, report))
}

/// Convert every dense FFN layer of a model. `profiles[l]` must hold the
/// calibration profile of layer `l`.
pub fn convert_model(
    model: &ModelWeights,
    profiles: &[ActivationProfile],
    spec: &MoeSpec,
    opts: &ConvertOptions,
) -> Result<ConvertedModel> {
    if profiles.len() != model.config.n_layers {
        bail!("need one profile per layer ({} != {})", profiles.len(), model.config.n_layers);
    }
    let mut out = model.clone();
    let mut report = ConvertReport::default();
    for (l, layer) in out.layers.iter_mut().enumerate() {
        let ffn = match &layer.ffn {
            LayerFfn::Dense(f) => f,
            LayerFfn::Moe(_) => bail!("layer {l} is already MoE; use hierarchical_convert"),
        };
        let (moe, r) = convert_ffn_timed(ffn, &profiles[l], spec, opts)
            .with_context(|| format!("layer {l}"))?;
        report.accumulate(&r);
        layer.ffn = LayerFfn::Moe(moe);
    }
    Ok(ConvertedModel { model: out, report })
}

/// Expected reconstruction error `E‖F_MoE(x) − F(x)‖ / E‖F(x)‖` on a
/// probe batch — the conversion-quality metric used by Table 5-style
/// ablations (lower is better).
pub fn reconstruction_error(
    ffn: &FfnWeights,
    moe: &MoeLayerWeights,
    probe: &crate::tensor::Tensor,
) -> f64 {
    let dense = crate::tensor::swiglu_ffn(probe, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
    let (sparse, _) = crate::moe::moe_ffn_forward(moe, probe);
    let mut diff = dense.clone();
    for (a, b) in diff.data.iter_mut().zip(&sparse.data) {
        *a -= b;
    }
    (diff.norm() / dense.norm().max(1e-12)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{swiglu_hidden, Tensor};
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    /// Random FFN + profile with planted structure: `hot` neurons always
    /// fire; the rest fire in `n_groups` correlated groups.
    fn planted(
        rng: &mut Rng,
        d: usize,
        d_h: usize,
        n_hot: usize,
        n_groups: usize,
        q: usize,
    ) -> (FfnWeights, ActivationProfile, Vec<usize>, Vec<usize>) {
        let ffn = FfnWeights {
            w_gate: Tensor::randn(rng, &[d, d_h], 0.4),
            w_up: Tensor::randn(rng, &[d, d_h], 0.4),
            w_down: Tensor::randn(rng, &[d_h, d], 0.4),
        };
        // choose hot neurons + group labels for the rest
        let mut ids: Vec<usize> = (0..d_h).collect();
        rng.shuffle(&mut ids);
        let hot: Vec<usize> = ids[..n_hot].to_vec();
        let rest: Vec<usize> = ids[n_hot..].to_vec();
        let mut group_of = vec![usize::MAX; d_h];
        for (k, &i) in rest.iter().enumerate() {
            group_of[i] = k % n_groups;
        }
        // synthesize hidden states: hot always large, one group active
        // per token
        let mut h = Tensor::zeros(&[q, d_h]);
        for t in 0..q {
            let g = rng.below(n_groups);
            let row = h.row_mut(t);
            for i in 0..d_h {
                row[i] = 0.01 * rng.normal();
            }
            for &i in &hot {
                row[i] = 3.0 + 0.1 * rng.normal();
            }
            for i in 0..d_h {
                if group_of[i] == g {
                    row[i] = 1.5 + 0.1 * rng.normal();
                }
            }
        }
        let k_a = n_hot + (d_h - n_hot) / n_groups;
        let prof = ActivationProfile::from_hidden(&h, k_a);
        (ffn, prof, hot, group_of)
    }

    #[test]
    fn conversion_is_a_permutation() {
        let mut rng = Rng::new(31);
        let (ffn, prof, _, _) = planted(&mut rng, 8, 64, 16, 6, 150);
        let spec: MoeSpec = "S2A3E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        assert_eq!(moe.covered_neurons(), (0..64).collect::<Vec<_>>());
        assert_eq!(moe.experts.len(), 6);
        for e in &moe.experts {
            assert_eq!(e.hidden_dim(), 8);
        }
        assert_eq!(moe.shared.hidden_dim(), 16);
    }

    #[test]
    fn shared_expert_captures_hot_neurons() {
        let mut rng = Rng::new(32);
        let (ffn, prof, hot, _) = planted(&mut rng, 8, 64, 16, 6, 200);
        let spec: MoeSpec = "S2A3E8".parse().unwrap(); // 2*8=16 shared slots
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        let shared: std::collections::HashSet<_> = moe.shared_neurons.iter().copied().collect();
        let captured = hot.iter().filter(|i| shared.contains(i)).count();
        assert!(captured >= 15, "only {captured}/16 hot neurons in shared expert");
    }

    #[test]
    fn clustering_recovers_planted_groups() {
        let mut rng = Rng::new(33);
        // 64 neurons: 16 hot, 48 in 6 groups of 8 → exactly E8 S2 layout
        let (ffn, prof, _, group_of) = planted(&mut rng, 8, 64, 16, 6, 300);
        let spec: MoeSpec = "S2A3E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        // each routed expert should be dominated by one planted group
        let mut pure = 0;
        for mem in &moe.expert_neurons {
            let mut counts = std::collections::HashMap::new();
            for &i in mem {
                *counts.entry(group_of[i]).or_insert(0usize) += 1;
            }
            let maj = counts.values().copied().max().unwrap();
            if maj as f64 >= 0.75 * mem.len() as f64 {
                pure += 1;
            }
        }
        assert!(pure >= 5, "only {pure}/6 experts are group-pure");
    }

    #[test]
    fn representatives_belong_to_their_expert() {
        let mut rng = Rng::new(34);
        let (ffn, prof, _, _) = planted(&mut rng, 8, 64, 16, 6, 150);
        let spec: MoeSpec = "S2A3E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        for (j, &r) in moe.representatives.iter().enumerate() {
            assert!(moe.expert_neurons[j].contains(&r), "rep {r} not in expert {j}");
        }
        // router columns match the representative neurons' weights
        let Router::Analytical(rw) = &moe.router else { panic!("expected analytical router") };
        for (j, &r) in moe.representatives.iter().enumerate() {
            for row in 0..8 {
                assert_eq!(rw.w_gate_r.at2(row, j), ffn.w_gate.at2(row, r));
                assert_eq!(rw.w_up_r.at2(row, j), ffn.w_up.at2(row, r));
            }
        }
    }

    #[test]
    fn expert_weights_match_original_columns() {
        let mut rng = Rng::new(35);
        let (ffn, prof, _, _) = planted(&mut rng, 8, 64, 16, 6, 100);
        let spec: MoeSpec = "S2A3E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        for (e, neurons) in moe.expert_neurons.iter().enumerate() {
            for (slot, &orig) in neurons.iter().enumerate() {
                for row in 0..8 {
                    assert_eq!(moe.experts[e].w_gate.at2(row, slot), ffn.w_gate.at2(row, orig));
                }
                assert_eq!(moe.experts[e].w_down.row(slot), ffn.w_down.row(orig));
            }
        }
    }

    #[test]
    fn router_ranks_active_group_highest() {
        // On a token where group g fires, the router's top choice should
        // be the expert holding group g (scores approximate expert
        // hidden-state magnitude, §4.2).
        let mut rng = Rng::new(36);
        let (ffn, prof, _, group_of) = planted(&mut rng, 8, 64, 16, 6, 300);
        let spec: MoeSpec = "S2A1E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        // map expert -> dominant planted group
        let dominant: Vec<usize> = moe
            .expert_neurons
            .iter()
            .map(|mem| {
                let mut counts = std::collections::HashMap::new();
                for &i in mem {
                    *counts.entry(group_of[i]).or_insert(0usize) += 1;
                }
                *counts.iter().max_by_key(|(_, &c)| c).unwrap().0
            })
            .collect();
        // build probe tokens that light up a known group: reuse the
        // planted generator's structure by sampling x and measuring which
        // group's neurons have max hidden response
        let x = Tensor::randn(&mut rng, &[64, 8], 1.0);
        let h = swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let dec = crate::moe::route_tokens(&moe, &x);
        let mut hits = 0;
        for t in 0..64 {
            // which expert has the largest true hidden L1?
            let mut best_e = 0;
            let mut best_l1 = -1.0f32;
            for (e, mem) in moe.expert_neurons.iter().enumerate() {
                let l1: f32 = mem.iter().map(|&i| h.at2(t, i).abs()).sum();
                if l1 > best_l1 {
                    best_l1 = l1;
                    best_e = e;
                }
            }
            if dec[t].experts[0] == best_e {
                hits += 1;
            }
        }
        let _ = dominant;
        // The analytical router scores through ONE representative neuron
        // per expert, so on unstructured gaussian probes it is a noisy
        // proxy — the paper's claim is "well above chance", not exact
        // agreement (chance here = 1/6 ≈ 10.7/64).
        assert!(hits >= 14, "router matched true-best expert only {hits}/64 times");
    }

    #[test]
    fn sparsity_monotonically_hurts_reconstruction() {
        let mut rng = Rng::new(37);
        let (ffn, prof, _, _) = planted(&mut rng, 8, 64, 16, 6, 200);
        let probe = Tensor::randn(&mut rng, &[64, 8], 1.0);
        let mut last = -1.0;
        for spec_s in ["S2A6E8", "S2A4E8", "S2A2E8"] {
            let spec: MoeSpec = spec_s.parse().unwrap();
            let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
            let err = reconstruction_error(&ffn, &moe, &probe);
            assert!(err >= last, "error not monotone at {spec_s}: {err} < {last}");
            last = err;
        }
    }

    #[test]
    fn convert_model_all_layers() {
        let mut rng = Rng::new(38);
        let cfg = crate::model::model_config("tiny").unwrap();
        let model = ModelWeights::random(&cfg, &mut rng);
        let x = Tensor::randn(&mut rng, &[80, cfg.d_model], 1.0);
        let profiles: Vec<ActivationProfile> = (0..cfg.n_layers)
            .map(|l| {
                let f = model.dense_ffn(l);
                let h = swiglu_hidden(&x, &f.w_gate, &f.w_up);
                ActivationProfile::from_hidden(&h, 16)
            })
            .collect();
        let spec: MoeSpec = "S3A3E8".parse().unwrap();
        let conv = convert_model(&model, &profiles, &spec, &ConvertOptions::default()).unwrap();
        assert_eq!(conv.report.layers, cfg.n_layers);
        assert!(conv.report.total.as_nanos() > 0);
        for l in &conv.model.layers {
            assert!(matches!(l.ffn, LayerFfn::Moe(_)));
        }
        // double conversion must fail
        assert!(convert_model(&conv.model, &profiles, &spec, &ConvertOptions::default()).is_err());
    }

    #[test]
    fn staged_partition_matches_fused_conversion() {
        // cmoe_layer_partition + analytical_router + assemble_moe_layer
        // IS convert_ffn — same membership, reps and router weights.
        let mut rng = Rng::new(39);
        let (ffn, prof, _, _) = planted(&mut rng, 8, 64, 16, 6, 150);
        let spec: MoeSpec = "S2A3E8".parse().unwrap();
        let opts = ConvertOptions::default();
        let fused = convert_ffn(&ffn, &prof, &spec, &opts).unwrap();
        let (part, _t) = cmoe_layer_partition(&prof, &spec, &opts).unwrap();
        assert_eq!(part.shared_neurons, fused.shared_neurons);
        assert_eq!(part.expert_neurons, fused.expert_neurons);
        assert_eq!(part.representatives.as_ref().unwrap(), &fused.representatives);
        part.validate(64).unwrap();
        let reps = part.representatives.clone().unwrap();
        let staged = assemble_moe_layer(
            &ffn,
            &part,
            RouterBuild {
                router: analytical_router(&ffn, &reps),
                representatives: reps,
                compensation: None,
            },
        );
        for (a, b) in staged.experts.iter().zip(&fused.experts) {
            assert_eq!(a.w_gate, b.w_gate);
            assert_eq!(a.w_down, b.w_down);
        }
        let (Router::Analytical(ra), Router::Analytical(rb)) = (&staged.router, &fused.router)
        else {
            panic!("router kinds differ")
        };
        assert_eq!(ra.w_gate_r, rb.w_gate_r);
        assert_eq!(ra.w_up_r, rb.w_up_r);
    }

    #[test]
    fn layer_partition_validate_catches_corruption() {
        let spec: MoeSpec = "S1A2E4".parse().unwrap();
        let good = LayerPartition {
            spec,
            shared_neurons: vec![0, 1],
            expert_neurons: vec![vec![2, 3], vec![4, 5], vec![6, 7]],
            representatives: Some(vec![2, 5, 6]),
        };
        good.validate(8).unwrap();
        // duplicated neuron
        let mut dup = good.clone();
        dup.expert_neurons[0] = vec![2, 2];
        assert!(dup.validate(8).is_err());
        // unbalanced expert
        let mut unb = good.clone();
        unb.expert_neurons[0] = vec![2, 3, 4];
        assert!(unb.validate(8).is_err());
        // representative outside its expert
        let mut rep = good.clone();
        rep.representatives = Some(vec![4, 5, 6]);
        assert!(rep.validate(8).is_err());
        // wrong width
        assert!(good.validate(12).is_err());
    }

    #[test]
    fn representative_neurons_lie_in_their_expert() {
        let mut rng = Rng::new(40);
        let (_, prof, _, _) = planted(&mut rng, 8, 64, 16, 6, 120);
        let partition: Vec<Vec<usize>> = (0..8).map(|e| (e * 8..(e + 1) * 8).collect()).collect();
        let reps = representative_neurons(&prof, &partition);
        assert_eq!(reps.len(), 8);
        for (e, r) in reps.iter().enumerate() {
            assert!(partition[e].contains(r), "rep {r} outside expert {e}");
        }
    }

    #[test]
    fn conversion_property_always_partitions() {
        check("convert-partition", Config { cases: 16, max_size: 4, ..Default::default() }, |rng, size| {
            let d = 4 + size;
            let n = [8usize, 16][rng.below(2)];
            let m = [2usize, 4][rng.below(2)];
            let d_h = n * m;
            let ffn = FfnWeights {
                w_gate: Tensor::randn(rng, &[d, d_h], 0.5),
                w_up: Tensor::randn(rng, &[d, d_h], 0.5),
                w_down: Tensor::randn(rng, &[d_h, d], 0.5),
            };
            let x = Tensor::randn(rng, &[40, d], 1.0);
            let h = swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
            let prof = ActivationProfile::from_hidden(&h, (d_h / 4).max(1));
            let shared = rng.range(1, n - 1);
            let routed = n - shared;
            let active = rng.range(1, routed + 1);
            let spec = MoeSpec::new(shared, active, n).unwrap();
            let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default())
                .map_err(|e| e.to_string())?;
            crate::prop_assert!(
                moe.covered_neurons() == (0..d_h).collect::<Vec<_>>(),
                "neurons lost/duplicated for {spec}"
            );
            crate::prop_assert!(moe.experts.len() == routed, "wrong expert count");
            crate::prop_assert!(
                moe.experts.iter().all(|e| e.hidden_dim() == m),
                "unbalanced expert sizes"
            );
            Ok(())
        });
    }
}
