//! Hierarchical restructuring (§4.4): apply CMoE recursively to the
//! routed experts of an existing MoE layer, producing two-level routing
//! — the top router picks primary experts, each expert's sub-router
//! picks sub-experts (Eq. 10).

use crate::converter::{convert_ffn, ConvertOptions};
use crate::model::{MoeLayerWeights, MoeSpec};
use crate::moe::{moe_ffn_forward, route_tokens};
use crate::profiling::ActivationProfile;
use crate::tensor::{self, Tensor};
use anyhow::Result;

/// A two-level MoE layer: the original top level plus one sub-MoE per
/// routed expert.
#[derive(Clone, Debug)]
pub struct HierMoeLayer {
    /// Top-level layer (its `experts` are retained for bookkeeping but
    /// forward uses the sub-layers).
    pub top: MoeLayerWeights,
    /// Sub-restructured version of each routed expert.
    pub sub: Vec<MoeLayerWeights>,
    pub sub_spec: MoeSpec,
}

impl HierMoeLayer {
    /// Effective fraction of FFN neurons active per token:
    /// shared + selected experts × (their shared + active fraction).
    pub fn active_fraction(&self) -> f64 {
        let top = &self.top.spec;
        let sub = &self.sub_spec;
        let shared_frac = top.shared as f64 / top.total as f64;
        let routed_frac = top.active as f64 / top.total as f64;
        shared_frac + routed_frac * sub.active_fraction()
    }
}

/// Build the per-expert activation profile by restricting a layer
/// profile to the expert's neuron columns.
fn restrict_profile(
    profile: &ActivationProfile,
    neurons: &[usize],
    k_a: usize,
) -> ActivationProfile {
    // Rebuild hidden "magnitudes" from the binary matrix restricted to
    // the expert's neurons; rates within the expert are re-derived from
    // per-neuron columns. We keep the binary columns as-is (the ATopK
    // selection was global, which matches how the top level profiles).
    let q = profile.q;
    let d_h = neurons.len();
    let mut a = vec![0u8; q * d_h];
    for t in 0..q {
        for (j, &i) in neurons.iter().enumerate() {
            a[t * d_h + j] = profile.a[t * profile.d_h + i];
        }
    }
    let mean_abs_h: Vec<f32> = neurons.iter().map(|&i| profile.mean_abs_h[i]).collect();
    ActivationProfile { d_h, q, k_a, a, mean_abs_h, h_sample: profile.h_sample.clone() }
}

/// Restructure each routed expert of `moe` into a sub-MoE with
/// `sub_spec`. `profile` is the original layer's activation profile.
pub fn hierarchical_convert(
    moe: &MoeLayerWeights,
    profile: &ActivationProfile,
    sub_spec: &MoeSpec,
    opts: &ConvertOptions,
) -> Result<HierMoeLayer> {
    let mut sub = Vec::with_capacity(moe.experts.len());
    for (e, expert) in moe.experts.iter().enumerate() {
        let p = restrict_profile(profile, &moe.expert_neurons[e], profile.k_a.min(expert.hidden_dim()));
        let s = convert_ffn(expert, &p, sub_spec, opts)?;
        sub.push(s);
    }
    Ok(HierMoeLayer { top: moe.clone(), sub, sub_spec: *sub_spec })
}

/// Two-level forward: top-level routing picks experts; each selected
/// expert computes through its own sub-MoE (Eq. 10). The top-level
/// shared expert stays dense.
pub fn hier_moe_forward(layer: &HierMoeLayer, x: &Tensor) -> Tensor {
    let _q = x.shape[0];
    let d = x.shape[1];
    let mut out = tensor::swiglu_ffn(
        x,
        &layer.top.shared.w_gate,
        &layer.top.shared.w_up,
        &layer.top.shared.w_down,
    );
    let decisions = route_tokens(&layer.top, x);
    let n_r = layer.top.spec.routed();
    let mut groups: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n_r];
    for (t, dec) in decisions.iter().enumerate() {
        for (k, &e) in dec.experts.iter().enumerate() {
            groups[e].push((t, dec.gates[k]));
        }
    }
    for (e, group) in groups.iter().enumerate() {
        if group.is_empty() {
            continue;
        }
        let idx: Vec<usize> = group.iter().map(|&(t, _)| t).collect();
        let xe = x.select_rows(&idx);
        let (ye, _) = moe_ffn_forward(&layer.sub[e], &xe);
        for (r, &(t, g)) in group.iter().enumerate() {
            let src = ye.row(r);
            let dst = &mut out.row_mut(t)[..d];
            for (o, v) in dst.iter_mut().zip(src) {
                *o += g * v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::FfnWeights;
    use crate::util::Rng;

    fn build_hier(rng: &mut Rng) -> (FfnWeights, MoeLayerWeights, HierMoeLayer) {
        let d = 8;
        let d_h = 128;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(rng, &[d, d_h], 0.4),
            w_up: Tensor::randn(rng, &[d, d_h], 0.4),
            w_down: Tensor::randn(rng, &[d_h, d], 0.4),
        };
        let x = Tensor::randn(rng, &[150, d], 1.0);
        let h = tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = ActivationProfile::from_hidden(&h, 24);
        let top_spec: MoeSpec = "S2A2E8".parse().unwrap(); // experts of 16 neurons
        let moe = convert_ffn(&ffn, &prof, &top_spec, &ConvertOptions::default()).unwrap();
        let sub_spec: MoeSpec = "S1A2E4".parse().unwrap(); // sub-experts of 4
        let hier = hierarchical_convert(&moe, &prof, &sub_spec, &ConvertOptions::default()).unwrap();
        (ffn, moe, hier)
    }

    #[test]
    fn hierarchy_shapes() {
        let mut rng = Rng::new(41);
        let (_, moe, hier) = build_hier(&mut rng);
        assert_eq!(hier.sub.len(), moe.experts.len());
        for s in &hier.sub {
            assert_eq!(s.experts.len(), 3); // E4 S1 → 3 routed
            assert_eq!(s.shared.hidden_dim(), 4);
            for e in &s.experts {
                assert_eq!(e.hidden_dim(), 4);
            }
        }
    }

    #[test]
    fn sub_conversion_partitions_each_expert() {
        let mut rng = Rng::new(42);
        let (_, moe, hier) = build_hier(&mut rng);
        for (e, s) in hier.sub.iter().enumerate() {
            // sub-layer neuron ids index *within* the expert slice
            assert_eq!(s.covered_neurons(), (0..moe.experts[e].hidden_dim()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn full_activation_hierarchy_matches_dense() {
        // top all-active + sub all-active must reproduce the dense FFN
        let mut rng = Rng::new(43);
        let d = 8;
        let d_h = 128;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[d, d_h], 0.4),
            w_up: Tensor::randn(&mut rng, &[d, d_h], 0.4),
            w_down: Tensor::randn(&mut rng, &[d_h, d], 0.4),
        };
        let xc = Tensor::randn(&mut rng, &[150, d], 1.0);
        let h = tensor::swiglu_hidden(&xc, &ffn.w_gate, &ffn.w_up);
        let prof = ActivationProfile::from_hidden(&h, 24);
        let top: MoeSpec = "S2A6E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &top, &ConvertOptions::default()).unwrap();
        let sub: MoeSpec = "S1A3E4".parse().unwrap();
        let hier = hierarchical_convert(&moe, &prof, &sub, &ConvertOptions::default()).unwrap();
        let x = Tensor::randn(&mut rng, &[10, d], 1.0);
        let dense = tensor::swiglu_ffn(&x, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
        let out = hier_moe_forward(&hier, &x);
        assert!(dense.max_abs_diff(&out) < 1e-4, "diff {}", dense.max_abs_diff(&out));
    }

    #[test]
    fn active_fraction_math() {
        let mut rng = Rng::new(44);
        let (_, _, hier) = build_hier(&mut rng);
        // top S2A2E8: 2/8 shared + 2/8 routed × sub S1A2E4 (3/4 active)
        let expect = 0.25 + 0.25 * 0.75;
        assert!((hier.active_fraction() - expect).abs() < 1e-12);
    }

    #[test]
    fn hier_sparser_than_top_alone() {
        let mut rng = Rng::new(45);
        let (_, moe, hier) = build_hier(&mut rng);
        assert!(hier.active_fraction() < moe.spec.active_fraction());
    }
}
