//! Calibration-corpus wiring, in one place.
//!
//! The CLI (`cmoe convert` / `cmoe profile`), the conversion
//! [`crate::pipeline`] and the bench harness's `Ctx` all need the same
//! recipe: generate a deterministic corpus slice, byte-tokenize it,
//! truncate to `examples × seq` tokens, and (for profiling) run the
//! dense forward to collect per-layer [`ActivationProfile`]s. This
//! module is the single implementation — the seeds here are the ones
//! every experiment shares, so calibration streams are reproducible
//! across the CLI, the pipeline and `cmoe bench`.

use crate::data::corpus::{gen_corpus, CorpusSpec, Domain};
use crate::data::encode;
use crate::model::ModelWeights;
use crate::profiling::{profile_dense_model, ActivationProfile};

/// Paper §5.1 defaults: 8 calibration examples of 256 tokens, ATopK
/// width `K_a = 10`.
pub const DEFAULT_EXAMPLES: usize = 8;
pub const DEFAULT_SEQ: usize = 256;
pub const DEFAULT_KA: usize = 10;
/// Base experiment seed; calibration and eval streams derive from it
/// with fixed xors so they never overlap.
pub const DEFAULT_SEED: u64 = 0xC0DE;

const CALIB_SALT: u64 = 0xCA11;
const EVAL_SALT: u64 = 0xE7A1;

/// A fully specified calibration setup. `Default` mirrors the paper's
/// §5.1 configuration on the markov (WikiText-2 stand-in) domain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CalibrationSpec {
    pub domain: Domain,
    /// Number of calibration examples (sequences).
    pub examples: usize,
    /// Tokens per example / profiling chunk length.
    pub seq: usize,
    /// ATopK parameter `K_a` for activation profiling.
    pub k_a: usize,
    /// Base seed; the calibration and eval corpora are salted from it.
    pub seed: u64,
}

impl Default for CalibrationSpec {
    fn default() -> Self {
        CalibrationSpec {
            domain: Domain::Markov,
            examples: DEFAULT_EXAMPLES,
            seq: DEFAULT_SEQ,
            k_a: DEFAULT_KA,
            seed: DEFAULT_SEED,
        }
    }
}

impl CalibrationSpec {
    /// Exactly `n_tokens` tokens from the calibration stream.
    pub fn tokens_of(&self, n_tokens: usize) -> Vec<usize> {
        let text = gen_corpus(&CorpusSpec {
            domain: self.domain,
            bytes: n_tokens + 64,
            seed: self.seed ^ CALIB_SALT,
        });
        let mut toks = encode(&text);
        toks.truncate(n_tokens);
        toks
    }

    /// The calibration token stream (`examples × seq` tokens).
    pub fn calib_tokens(&self) -> Vec<usize> {
        self.tokens_of(self.examples * self.seq)
    }

    /// Held-out evaluation tokens (different salt from calibration, so
    /// eval text never leaks into profiling or fine-tuning).
    pub fn eval_tokens(&self, n_tokens: usize) -> Vec<usize> {
        let text = gen_corpus(&CorpusSpec {
            domain: self.domain,
            bytes: n_tokens + 64,
            seed: self.seed ^ EVAL_SALT,
        });
        let mut toks = encode(&text);
        toks.truncate(n_tokens);
        toks
    }

    /// Per-layer activation profiles of `model` on the calibration
    /// stream — the pipeline's profile stage.
    pub fn profiles(&self, model: &ModelWeights) -> Vec<ActivationProfile> {
        profile_dense_model(model, &self.calib_tokens(), self.seq, self.k_a)
    }

    /// The same spec pointed at another domain (Read-ME's auxiliary
    /// calibration domains; Table 4's source sweep).
    pub fn with_domain(&self, domain: Domain) -> CalibrationSpec {
        CalibrationSpec { domain, ..self.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calib_and_eval_streams_differ() {
        let spec = CalibrationSpec { examples: 2, seq: 64, ..Default::default() };
        let calib = spec.calib_tokens();
        let eval = spec.eval_tokens(128);
        assert_eq!(calib.len(), 128);
        assert_eq!(eval.len(), 128);
        assert_ne!(calib, eval, "calibration and eval corpora must not alias");
    }

    #[test]
    fn tokens_are_deterministic_in_seed() {
        let a = CalibrationSpec::default().tokens_of(100);
        let b = CalibrationSpec::default().tokens_of(100);
        assert_eq!(a, b);
        let c = CalibrationSpec { seed: 1, ..Default::default() }.tokens_of(100);
        assert_ne!(a, c);
    }

    #[test]
    fn with_domain_changes_stream() {
        let spec = CalibrationSpec { examples: 1, seq: 64, ..Default::default() };
        let a = spec.calib_tokens();
        let b = spec.with_domain(Domain::Arith).calib_tokens();
        assert_ne!(a, b);
    }

    #[test]
    fn profiles_cover_every_layer() {
        let cfg = crate::model::model_config("tiny").unwrap();
        let mut rng = crate::util::Rng::new(9);
        let model = ModelWeights::random(&cfg, &mut rng);
        let spec = CalibrationSpec { examples: 1, seq: 48, k_a: 8, ..Default::default() };
        let profiles = spec.profiles(&model);
        assert_eq!(profiles.len(), cfg.n_layers);
        assert!(profiles.iter().all(|p| p.d_h == cfg.d_ff && p.q == 48));
    }
}
