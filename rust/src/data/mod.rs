//! Synthetic corpora and tokenization.
//!
//! Two distribution-distinct domains substitute for WikiText-2 / C4
//! (docs/ARCHITECTURE.md module map: `data`):
//! * **markov** — character-level text from a fixed-order Markov chain
//!   over a word lexicon (natural-language-ish statistics).
//! * **arith** — compositional arithmetic/pattern sequences with exact
//!   structure (`a+b=c;` with carries, plus pattern-completion strings),
//!   giving the model something *learnable* so PPL and task accuracy are
//!   meaningful.
//!
//! Tokenization is byte-level (vocab 256) so the rust and python sides
//! agree trivially.

pub mod calibration;
pub mod corpus;
pub mod tasks_gen;

pub use calibration::CalibrationSpec;
pub use corpus::{gen_corpus, CorpusSpec, Domain};
pub use tasks_gen::{gen_choice_tasks, ChoiceTask};

/// Byte-level tokenizer: text ⇄ token ids (identity on bytes).
pub fn encode(text: &str) -> Vec<usize> {
    text.bytes().map(|b| b as usize).collect()
}

/// Decode token ids back to text (lossy on invalid UTF-8).
pub fn decode(tokens: &[usize]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| t as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let s = "3+4=7;12+9=21;";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn encode_is_byte_level() {
        assert_eq!(encode("AB"), vec![65, 66]);
        assert!(encode("hello").iter().all(|&t| t < 256));
    }
}
