//! Deterministic synthetic corpus generation (shared with
//! `python/compile/datagen.py`, which implements the identical
//! generators on the identical PCG stream so pretraining and evaluation
//! see the same distribution).

use crate::util::Rng;

/// Corpus domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// Markov-chain word text (WikiText-2 stand-in).
    Markov,
    /// Arithmetic + pattern strings (C4/structured stand-in).
    Arith,
}

impl Domain {
    pub fn parse(s: &str) -> Option<Domain> {
        match s {
            "markov" => Some(Domain::Markov),
            "arith" => Some(Domain::Arith),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Domain::Markov => "markov",
            Domain::Arith => "arith",
        }
    }
}

/// Corpus request.
#[derive(Clone, Copy, Debug)]
pub struct CorpusSpec {
    pub domain: Domain,
    pub bytes: usize,
    pub seed: u64,
}

/// Small word lexicon for the markov domain (stable order matters —
/// python mirrors it).
const LEXICON: &[&str] = &[
    "the", "model", "expert", "router", "token", "layer", "neuron", "dense", "sparse", "gate",
    "shared", "routed", "cache", "batch", "serve", "fast", "slow", "high", "low", "with", "from",
    "into", "over", "under", "runs", "emits", "learns", "splits", "merges", "activates",
];

/// Generate a corpus string of roughly `spec.bytes` bytes.
pub fn gen_corpus(spec: &CorpusSpec) -> String {
    let mut rng = Rng::new(spec.seed ^ (spec.domain as u64).wrapping_mul(0x9E37_79B9));
    match spec.domain {
        Domain::Markov => gen_markov(&mut rng, spec.bytes),
        Domain::Arith => gen_arith(&mut rng, spec.bytes),
    }
}

fn gen_markov(rng: &mut Rng, bytes: usize) -> String {
    // Order-1 Markov over the lexicon with a deterministic transition
    // structure: word i prefers words (2i+1) and (3i+2) mod N, giving
    // non-uniform, learnable bigram statistics.
    let n = LEXICON.len();
    let mut out = String::with_capacity(bytes + 16);
    let mut cur = rng.below(n);
    while out.len() < bytes {
        out.push_str(LEXICON[cur]);
        out.push(' ');
        let r = rng.f32();
        cur = if r < 0.45 {
            (2 * cur + 1) % n
        } else if r < 0.8 {
            (3 * cur + 2) % n
        } else {
            rng.below(n)
        };
        if rng.f32() < 0.07 {
            out.pop();
            out.push_str(". ");
        }
    }
    out.truncate(bytes);
    out
}

fn gen_arith(rng: &mut Rng, bytes: usize) -> String {
    // interleave addition equations and letter patterns
    let mut out = String::with_capacity(bytes + 32);
    while out.len() < bytes {
        if rng.f32() < 0.7 {
            let a = rng.below(100);
            let b = rng.below(100);
            out.push_str(&format!("{a}+{b}={};", a + b));
        } else {
            // pattern: abcabcabc…
            let period = rng.range(2, 5);
            let reps = rng.range(2, 5);
            let start = b'a' + rng.below(6) as u8;
            for _ in 0..reps {
                for k in 0..period {
                    out.push((start + k as u8) as char);
                }
            }
            out.push(';');
        }
    }
    out.truncate(bytes);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = CorpusSpec { domain: Domain::Markov, bytes: 500, seed: 7 };
        assert_eq!(gen_corpus(&spec), gen_corpus(&spec));
    }

    #[test]
    fn domains_differ() {
        let a = gen_corpus(&CorpusSpec { domain: Domain::Markov, bytes: 300, seed: 7 });
        let b = gen_corpus(&CorpusSpec { domain: Domain::Arith, bytes: 300, seed: 7 });
        assert_ne!(a, b);
        assert!(b.contains('+') && b.contains('='));
        assert!(!a.contains('+'));
    }

    #[test]
    fn requested_size() {
        for bytes in [10, 100, 4096] {
            let s = gen_corpus(&CorpusSpec { domain: Domain::Arith, bytes, seed: 1 });
            assert_eq!(s.len(), bytes);
        }
    }

    #[test]
    fn arith_equations_are_correct() {
        let s = gen_corpus(&CorpusSpec { domain: Domain::Arith, bytes: 2000, seed: 3 });
        let mut checked = 0;
        for part in s.split(';') {
            if let Some((lhs, rhs)) = part.split_once('=') {
                if let Some((a, b)) = lhs.split_once('+') {
                    if let (Ok(a), Ok(b), Ok(c)) =
                        (a.parse::<u64>(), b.parse::<u64>(), rhs.parse::<u64>())
                    {
                        assert_eq!(a + b, c, "bad equation {part}");
                        checked += 1;
                    }
                }
            }
        }
        assert!(checked > 10, "too few equations parsed: {checked}");
    }

    #[test]
    fn markov_bigrams_are_skewed() {
        // the transition structure must create non-uniform bigrams —
        // that's what makes the corpus learnable
        let s = gen_corpus(&CorpusSpec { domain: Domain::Markov, bytes: 50_000, seed: 11 });
        let words: Vec<&str> = s.split_whitespace().collect();
        let mut follow_the = std::collections::HashMap::new();
        for w in words.windows(2) {
            if w[0] == "the" {
                *follow_the.entry(w[1]).or_insert(0usize) += 1;
            }
        }
        let total: usize = follow_the.values().sum();
        let max = follow_the.values().copied().max().unwrap_or(0);
        assert!(
            max as f64 > total as f64 * 0.2,
            "bigram distribution too uniform: max {max}/{total}"
        );
    }
}
