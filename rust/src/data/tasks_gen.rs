//! Synthetic multiple-choice task generation — the stand-in for
//! PIQA/ARC/HellaSwag/MMLU-style suites (docs/ARCHITECTURE.md module
//! map: `data`). Each task is a
//! context plus `n_choices` completions exactly one of which continues
//! the context under the corpus's generative rules; models are scored
//! by likelihood ranking, the same protocol lm-eval uses.

use crate::util::Rng;

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct ChoiceTask {
    pub context: String,
    pub choices: Vec<String>,
    pub answer: usize,
}

/// Task families, roughly ordered by difficulty. The "knowledge" family
/// plays the MMLU role (recall of the lexicon's transition rules), the
/// "arith" family plays GSM8K (multi-digit addition), the "pattern"
/// family plays HellaSwag (sequence completion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskFamily {
    /// Next-word under the markov transition rules.
    Knowledge,
    /// `a+b=?` with numeric distractors.
    Arith,
    /// Periodic pattern completion.
    Pattern,
}

impl TaskFamily {
    pub fn name(&self) -> &'static str {
        match self {
            TaskFamily::Knowledge => "knowledge",
            TaskFamily::Arith => "arith",
            TaskFamily::Pattern => "pattern",
        }
    }
}

/// Generate `n` items of a family.
pub fn gen_choice_tasks(family: TaskFamily, n: usize, seed: u64) -> Vec<ChoiceTask> {
    let mut rng = Rng::new(seed ^ (family as u64).wrapping_mul(0xABCD_1234));
    (0..n)
        .map(|_| match family {
            TaskFamily::Knowledge => knowledge_item(&mut rng),
            TaskFamily::Arith => arith_item(&mut rng),
            TaskFamily::Pattern => pattern_item(&mut rng),
        })
        .collect()
}

const LEXICON: &[&str] = &[
    "the", "model", "expert", "router", "token", "layer", "neuron", "dense", "sparse", "gate",
    "shared", "routed", "cache", "batch", "serve", "fast", "slow", "high", "low", "with", "from",
    "into", "over", "under", "runs", "emits", "learns", "splits", "merges", "activates",
];

fn knowledge_item(rng: &mut Rng) -> ChoiceTask {
    // context ends on word w; the dominant continuation is (2w+1) mod N
    let n = LEXICON.len();
    let mut cur = rng.below(n);
    let mut ctx = String::new();
    for _ in 0..rng.range(3, 7) {
        ctx.push_str(LEXICON[cur]);
        ctx.push(' ');
        cur = (2 * cur + 1) % n;
    }
    ctx.push_str(LEXICON[cur]);
    ctx.push(' ');
    let answer_word = LEXICON[(2 * cur + 1) % n];
    let mut choices = vec![answer_word.to_string()];
    while choices.len() < 4 {
        let w = LEXICON[rng.below(n)];
        if w != answer_word && !choices.iter().any(|c| c == w) {
            choices.push(w.to_string());
        }
    }
    shuffle_with_answer(rng, ctx, choices)
}

fn arith_item(rng: &mut Rng) -> ChoiceTask {
    let a = rng.below(100);
    let b = rng.below(100);
    let c = a + b;
    let ctx = format!("{a}+{b}=");
    let mut wrongs = Vec::new();
    for delta in [1i64, -1, 10] {
        let w = (c as i64 + delta).max(0) as usize;
        if w != c {
            wrongs.push(format!("{w};"));
        }
    }
    let mut choices = vec![format!("{c};")];
    choices.extend(wrongs.into_iter().take(3));
    shuffle_with_answer(rng, ctx, choices)
}

fn pattern_item(rng: &mut Rng) -> ChoiceTask {
    let period = rng.range(2, 5);
    let start = b'a' + rng.below(6) as u8;
    let unit: String = (0..period).map(|k| (start + k as u8) as char).collect();
    let ctx = format!("{0}{0}{1}", unit, &unit[..period - 1]);
    let correct = unit.chars().last().unwrap().to_string();
    let mut choices = vec![correct.clone()];
    let mut c = b'a';
    while choices.len() < 4 {
        let s = (c as char).to_string();
        if s != correct && !choices.contains(&s) {
            choices.push(s);
        }
        c += 1;
    }
    shuffle_with_answer(rng, ctx, choices)
}

fn shuffle_with_answer(rng: &mut Rng, context: String, mut choices: Vec<String>) -> ChoiceTask {
    // choices[0] is correct; shuffle and track it
    let correct = choices[0].clone();
    rng.shuffle(&mut choices);
    let answer = choices.iter().position(|c| *c == correct).unwrap();
    ChoiceTask { context, choices, answer }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = gen_choice_tasks(TaskFamily::Arith, 10, 3);
        let b = gen_choice_tasks(TaskFamily::Arith, 10, 3);
        assert_eq!(a.len(), 10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn arith_answers_are_correct_sums() {
        for t in gen_choice_tasks(TaskFamily::Arith, 50, 5) {
            let lhs = t.context.trim_end_matches('=');
            let (a, b) = lhs.split_once('+').unwrap();
            let sum: usize = a.parse::<usize>().unwrap() + b.parse::<usize>().unwrap();
            assert_eq!(t.choices[t.answer], format!("{sum};"));
        }
    }

    #[test]
    fn four_distinct_choices() {
        for fam in [TaskFamily::Knowledge, TaskFamily::Arith, TaskFamily::Pattern] {
            for t in gen_choice_tasks(fam, 30, 9) {
                assert_eq!(t.choices.len(), 4, "{fam:?}");
                let mut c = t.choices.clone();
                c.sort();
                c.dedup();
                assert_eq!(c.len(), 4, "{fam:?} duplicate choices {:?}", t.choices);
                assert!(t.answer < 4);
            }
        }
    }

    #[test]
    fn pattern_answer_completes_period() {
        for t in gen_choice_tasks(TaskFamily::Pattern, 30, 11) {
            let full = format!("{}{}", t.context, t.choices[t.answer]);
            // the completed string must be periodic with some period 2..5
            let ok = (2..5).any(|p| full.bytes().enumerate().all(|(i, b)| {
                i < p || b == full.as_bytes()[i - p]
            }));
            assert!(ok, "completion not periodic: {full}");
        }
    }
}
