//! Post-training weight quantization substrate (paper §6: "FFN
//! restructuring integrates well with post-training quantization …
//! because the operation preserves layer interfaces").
//!
//! Implements symmetric per-output-channel int8 weight quantization
//! (the W8 setting of AWQ-style PTQ) with simulated dequantized
//! execution, applicable to dense models *and* CMoE-restructured models
//! — the composition test in this module is the §6 claim made
//! executable.

use crate::model::{FfnWeights, LayerFfn, ModelWeights};
use crate::tensor::{self, Tensor};

/// Symmetric int8 code range: values quantize to `[-127, 127]` (the
/// symmetric subset of i8 — `-128` is never produced, so negation is
/// always exact). Registered with the `cmoe lint` mirror-drift rule
/// against `scripts/mirror_quant.py`.
pub const INT8_CLAMP: f32 = 127.0;

/// Columns whose max |w| is at or below this epsilon are treated as
/// all-zero and get scale 1.0 (a zero column would otherwise divide by
/// zero). Drift-registered like [`INT8_CLAMP`].
pub const SCALE_EPS: f32 = 0.00000001;

/// A symmetric int8 per-column quantized matrix.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub shape: Vec<usize>,
    /// One scale per output column (last dim).
    pub scales: Vec<f32>,
    pub data: Vec<i8>,
}

impl QuantizedTensor {
    /// Quantize a 2-D tensor column-wise: `q = round(w / s)`,
    /// `s = max|w_col| / 127`.
    pub fn quantize(w: &Tensor) -> QuantizedTensor {
        assert_eq!(w.rank(), 2);
        let (r, c) = (w.shape[0], w.shape[1]);
        let mut scales = vec![0.0f32; c];
        for i in 0..r {
            for (j, s) in scales.iter_mut().enumerate() {
                *s = s.max(w.at2(i, j).abs());
            }
        }
        for s in scales.iter_mut() {
            *s = if *s > SCALE_EPS { *s / INT8_CLAMP } else { 1.0 };
        }
        let mut data = vec![0i8; r * c];
        for i in 0..r {
            for j in 0..c {
                let q = (w.at2(i, j) / scales[j]).round();
                data[i * c + j] = q.clamp(-INT8_CLAMP, INT8_CLAMP) as i8;
            }
        }
        QuantizedTensor { shape: w.shape.clone(), scales, data }
    }

    /// Dequantize back to f32 (simulated-quantization execution).
    pub fn dequantize(&self) -> Tensor {
        let c = self.shape[1];
        let data = self
            .data
            .iter()
            .enumerate()
            .map(|(k, &q)| q as f32 * self.scales[k % c])
            .collect();
        Tensor::from_vec(data, &self.shape)
    }

    /// Worst-case absolute rounding error of this quantization.
    pub fn max_error_bound(&self) -> f32 {
        self.scales.iter().cloned().fold(0.0, f32::max) * 0.5
    }

    /// Bytes of the quantized representation (int8 + f32 scales).
    pub fn quantized_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }
}

/// An expert FFN held in int8: the three projections of a SwiGLU FFN
/// quantized per output column, executable directly via the fused
/// dequant kernel [`tensor::matmul_rows_q8`] — no f32 copy of the
/// weights ever materializes on the forward path. This is the storage
/// form behind `Int8Resident` / `Int8Host` in [`crate::moe::ExpertStore`].
#[derive(Clone, Debug)]
pub struct QuantizedFfn {
    pub w_gate: QuantizedTensor,
    pub w_up: QuantizedTensor,
    pub w_down: QuantizedTensor,
}

/// Upper bound on |silu(a) − silu(b)| / |a − b|: silu's derivative
/// peaks at ≈ 1.0998, so 1.1 is a safe Lipschitz constant for the
/// divergence-bound interval propagation below.
const SILU_LIP: f32 = 1.1;

impl QuantizedFfn {
    pub fn quantize(ffn: &FfnWeights) -> QuantizedFfn {
        QuantizedFfn {
            w_gate: QuantizedTensor::quantize(&ffn.w_gate),
            w_up: QuantizedTensor::quantize(&ffn.w_up),
            w_down: QuantizedTensor::quantize(&ffn.w_down),
        }
    }

    /// Simulated-dequantization round trip (testing / fallback).
    pub fn dequantize(&self) -> FfnWeights {
        FfnWeights {
            w_gate: self.w_gate.dequantize(),
            w_up: self.w_up.dequantize(),
            w_down: self.w_down.dequantize(),
        }
    }

    /// Hidden (neuron) dimension, mirroring [`FfnWeights::hidden_dim`].
    pub fn hidden_dim(&self) -> usize {
        self.w_gate.shape[1]
    }

    /// Model width `d` (input dim of the gate projection).
    pub fn model_dim(&self) -> usize {
        self.w_gate.shape[0]
    }

    /// Bytes of the int8 representation, scales included.
    pub fn quantized_bytes(&self) -> usize {
        self.w_gate.quantized_bytes()
            + self.w_up.quantized_bytes()
            + self.w_down.quantized_bytes()
    }

    /// Quantized grouped SwiGLU over a flat block of rows — the int8
    /// twin of [`tensor::swiglu_rows_into`], same scratch contract,
    /// all three GEMMs through [`tensor::matmul_rows_q8`] so the
    /// k-accumulation order matches the fp32 band kernel.
    // lint: hot-path
    pub fn swiglu_rows_into(
        &self,
        x_rows: &[f32],
        hidden: &mut [f32],
        up: &mut [f32],
        out: &mut [f32],
    ) {
        let d = self.model_dim();
        let m = self.hidden_dim();
        debug_assert_eq!(self.w_up.shape, [d, m]);
        debug_assert_eq!(self.w_down.shape, [m, d]);
        debug_assert_eq!(x_rows.len() % d, 0);
        let rows = x_rows.len() / d;
        let (hidden, up) = (&mut hidden[..rows * m], &mut up[..rows * m]);
        let out = &mut out[..rows * d];
        tensor::matmul_rows_q8(x_rows, &self.w_gate.data, &self.w_gate.scales, hidden, d, m);
        tensor::matmul_rows_q8(x_rows, &self.w_up.data, &self.w_up.scales, up, d, m);
        for (h, u) in hidden.iter_mut().zip(up.iter()) {
            *h = tensor::silu(*h) * *u;
        }
        tensor::matmul_rows_q8(hidden, &self.w_down.data, &self.w_down.scales, out, m, d);
    }

    /// Analytic per-call bound on the max-abs divergence between this
    /// quantized FFN's output and the fp32 original's, over the given
    /// input rows — the `max_error_bound` composition the property
    /// suite checks the real divergence against. Interval propagation:
    /// each projection's elementwise weight error is at most its
    /// [`QuantizedTensor::max_error_bound`], an input row contributes
    /// `Σ|x|` of it per output element, the SwiGLU gate is
    /// [`SILU_LIP`]-Lipschitz, and the down projection sees both the
    /// hidden error and its own weight error. Not tight — it is a
    /// soundness bound, not an estimate.
    pub fn divergence_bound(&self, x_rows: &[f32]) -> f32 {
        let d = self.model_dim();
        let m = self.hidden_dim();
        assert_eq!(x_rows.len() % d, 0);
        let rows = x_rows.len() / d;
        if rows == 0 {
            return 0.0;
        }
        let bg = self.w_gate.max_error_bound();
        let bu = self.w_up.max_error_bound();
        let bd = self.w_down.max_error_bound();
        // max |dequantized w_down| — |w_down_fp| ≤ this + bd elementwise
        let wd_max = self
            .w_down
            .data
            .iter()
            .enumerate()
            .map(|(k, &q)| (q as f32 * self.w_down.scales[k % d]).abs())
            .fold(0.0f32, f32::max);
        let mut hidden = vec![0.0f32; rows * m];
        let mut up = vec![0.0f32; rows * m];
        tensor::matmul_rows_q8(x_rows, &self.w_gate.data, &self.w_gate.scales, &mut hidden, d, m);
        tensor::matmul_rows_q8(x_rows, &self.w_up.data, &self.w_up.scales, &mut up, d, m);
        let mut worst = 0.0f32;
        for r in 0..rows {
            let x_abs: f32 = x_rows[r * d..(r + 1) * d].iter().map(|v| v.abs()).sum();
            let dg = x_abs * bg; // |g_q − g_fp| per hidden element
            let du = x_abs * bu; // |u_q − u_fp| per hidden element
            let mut sum_h = 0.0f32; // Σ |h_q|
            let mut sum_dh = 0.0f32; // Σ per-element hidden error bound
            for i in 0..m {
                let g = hidden[r * m + i];
                let u = up[r * m + i];
                let sg = tensor::silu(g).abs();
                sum_h += sg * u.abs();
                sum_dh += sg * du + (u.abs() + du) * SILU_LIP * dg;
            }
            worst = worst.max(sum_h * bd + sum_dh * (wd_max + bd));
        }
        worst
    }
}

/// Round-trip quantize an FFN (weights replaced by their dequantized
/// int8 versions — interface unchanged, which is the point).
pub fn quantize_ffn(ffn: &FfnWeights) -> FfnWeights {
    FfnWeights {
        w_gate: QuantizedTensor::quantize(&ffn.w_gate).dequantize(),
        w_up: QuantizedTensor::quantize(&ffn.w_up).dequantize(),
        w_down: QuantizedTensor::quantize(&ffn.w_down).dequantize(),
    }
}

/// Quantize every projection of a model (attention + FFN/experts +
/// router + unembedding). Works on dense AND converted models.
pub fn quantize_model(model: &ModelWeights) -> ModelWeights {
    let q = |t: &Tensor| QuantizedTensor::quantize(t).dequantize();
    let mut out = model.clone();
    out.embed = q(&out.embed);
    out.unembed = q(&out.unembed);
    for layer in out.layers.iter_mut() {
        layer.attn.wq = q(&layer.attn.wq);
        layer.attn.wk = q(&layer.attn.wk);
        layer.attn.wv = q(&layer.attn.wv);
        layer.attn.wo = q(&layer.attn.wo);
        match &mut layer.ffn {
            LayerFfn::Dense(f) => *f = quantize_ffn(f),
            LayerFfn::Moe(moe) => {
                moe.shared = quantize_ffn(&moe.shared);
                for e in moe.experts.iter_mut() {
                    *e = quantize_ffn(e);
                }
                if let crate::model::Router::Analytical(rw) = &mut moe.router {
                    rw.w_gate_r = q(&rw.w_gate_r);
                    rw.w_up_r = q(&rw.w_up_r);
                }
            }
        }
    }
    out
}

/// The projection matrices [`quantize_model`] quantizes, in the same
/// order — the single source of truth for byte accounting.
fn quantized_projections(model: &ModelWeights) -> Vec<&Tensor> {
    let mut ts = vec![&model.embed, &model.unembed];
    for layer in &model.layers {
        ts.extend([&layer.attn.wq, &layer.attn.wk, &layer.attn.wv, &layer.attn.wo]);
        match &layer.ffn {
            LayerFfn::Dense(f) => ts.extend([&f.w_gate, &f.w_up, &f.w_down]),
            LayerFfn::Moe(moe) => {
                ts.extend([&moe.shared.w_gate, &moe.shared.w_up, &moe.shared.w_down]);
                for e in &moe.experts {
                    ts.extend([&e.w_gate, &e.w_up, &e.w_down]);
                }
                if let crate::model::Router::Analytical(rw) = &moe.router {
                    ts.extend([&rw.w_gate_r, &rw.w_up_r]);
                }
            }
        }
    }
    ts
}

/// Compression ratio of int8 weights vs f32 for the model at hand:
/// fp32 bytes over actual [`QuantizedTensor::quantized_bytes`] across
/// every projection [`quantize_model`] touches. Strictly below 4× —
/// the per-column f32 scales are not free, and at small row counts
/// (expert slices are `[d, m]` with small `m`) they cost a visible
/// fraction of the int8 payload.
pub fn compression_ratio(model: &ModelWeights) -> f64 {
    let mut q_bytes = 0usize;
    let mut f_bytes = 0usize;
    for t in quantized_projections(model) {
        q_bytes += t.numel() + t.shape[1] * 4;
        f_bytes += t.numel() * 4;
    }
    if q_bytes == 0 {
        return 1.0;
    }
    f_bytes as f64 / q_bytes as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::model_config;
    use crate::util::Rng;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let mut rng = Rng::new(501);
        let w = Tensor::randn(&mut rng, &[64, 32], 0.5);
        let q = QuantizedTensor::quantize(&w);
        let back = q.dequantize();
        let err = w.max_abs_diff(&back);
        assert!(err <= q.max_error_bound() + 1e-6, "err {err} > bound {}", q.max_error_bound());
        assert!(err > 0.0, "suspiciously exact");
        // int8 + scales is ~4x smaller
        assert!(q.quantized_bytes() < w.numel() * 4 / 3);
    }

    #[test]
    fn zero_column_is_stable() {
        let mut w = Tensor::zeros(&[4, 3]);
        w.data[0] = 1.0; // col 0 nonzero, col 1/2 all-zero
        let q = QuantizedTensor::quantize(&w);
        let back = q.dequantize();
        assert!(w.max_abs_diff(&back) < 1e-2);
        assert!(back.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_ffn_matches_simulated_dequant_and_bounds_divergence() {
        let mut rng = Rng::new(505);
        let (d, m, rows) = (12, 24, 7);
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[d, m], 0.5),
            w_up: Tensor::randn(&mut rng, &[d, m], 0.5),
            w_down: Tensor::randn(&mut rng, &[m, d], 0.5),
        };
        let q = QuantizedFfn::quantize(&ffn);
        let x = Tensor::randn(&mut rng, &[rows, d], 1.0);
        let mut hidden = vec![0.0f32; rows * m];
        let mut up = vec![0.0f32; rows * m];
        let mut out_q = vec![0.0f32; rows * d];
        q.swiglu_rows_into(&x.data, &mut hidden, &mut up, &mut out_q);
        // fused-dequant path == simulated dequant through the fp32 kernel
        let deq = q.dequantize();
        let mut out_sim = vec![0.0f32; rows * d];
        crate::tensor::swiglu_rows_into(
            &x.data,
            &deq.w_gate,
            &deq.w_up,
            &deq.w_down,
            &mut hidden,
            &mut up,
            &mut out_sim,
        );
        for (a, b) in out_q.iter().zip(&out_sim) {
            assert!((a - b).abs() < 1e-3, "fused dequant diverged: {a} vs {b}");
        }
        // and the fp32 original stays inside the analytic bound
        let mut out_fp = vec![0.0f32; rows * d];
        crate::tensor::swiglu_rows_into(
            &x.data,
            &ffn.w_gate,
            &ffn.w_up,
            &ffn.w_down,
            &mut hidden,
            &mut up,
            &mut out_fp,
        );
        let bound = q.divergence_bound(&x.data);
        let worst = out_q
            .iter()
            .zip(&out_fp)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(worst > 0.0, "int8 suspiciously exact");
        assert!(worst <= bound * 1.01 + 1e-4, "divergence {worst} > bound {bound}");
    }

    #[test]
    fn compression_ratio_reflects_scale_overhead() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(506);
        let model = ModelWeights::random(&cfg, &mut rng);
        let r = compression_ratio(&model);
        // int8 payload alone is 4x; per-column f32 scales pull it below
        assert!(r > 3.0 && r < 4.0, "ratio {r} outside (3, 4)");
        // exact accounting on one known tensor: [64, 32] fp32 vs int8+scales
        let w = Tensor::randn(&mut rng, &[64, 32], 0.5);
        let q = QuantizedTensor::quantize(&w);
        assert_eq!(q.quantized_bytes(), 64 * 32 + 32 * 4);
    }

    #[test]
    fn quantization_composes_with_cmoe() {
        // §6: quantize-then-convert ≈ convert-then-quantize ≈ dense
        use crate::converter::{convert_ffn, reconstruction_error, ConvertOptions};
        use crate::profiling::ActivationProfile;
        let mut rng = Rng::new(502);
        let planted = crate::testutil::structured_ffn(&mut rng, 10, 64, 16, 6);
        let ffn = planted.ffn;
        let x = Tensor::randn(&mut rng, &[256, 10], 1.0);
        let h = crate::tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = ActivationProfile::from_hidden(&h, 12);
        let spec = "S2A4E8".parse().unwrap();

        let moe_fp = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        // convert-then-quantize
        let mut moe_q = moe_fp.clone();
        moe_q.shared = quantize_ffn(&moe_q.shared);
        for e in moe_q.experts.iter_mut() {
            *e = quantize_ffn(e);
        }
        let probe = Tensor::randn(&mut rng, &[128, 10], 1.0);
        let e_fp = reconstruction_error(&ffn, &moe_fp, &probe);
        let e_q = reconstruction_error(&ffn, &moe_q, &probe);
        assert!(
            (e_q - e_fp).abs() < 0.05,
            "quantization changed MoE reconstruction too much: {e_fp:.4} -> {e_q:.4}"
        );
    }

    #[test]
    fn quantized_model_ppl_close_to_fp32() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(503);
        let model = ModelWeights::random(&cfg, &mut rng);
        let qm = quantize_model(&model);
        let toks: Vec<usize> = (0..192).map(|_| rng.below(cfg.vocab)).collect();
        let p_fp = crate::eval::perplexity(&model, &toks, 64);
        let p_q = crate::eval::perplexity(&qm, &toks, 64);
        assert!(
            (p_q / p_fp - 1.0).abs() < 0.05,
            "int8 PPL drift too large: {p_fp:.2} -> {p_q:.2}"
        );
    }

    #[test]
    fn quantize_converted_model_end_to_end() {
        use crate::converter::{convert_model, ConvertOptions};
        use crate::eval::forward::DenseForward;
        use crate::profiling::ActivationProfile;
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(504);
        let model = ModelWeights::random(&cfg, &mut rng);
        let calib: Vec<usize> = (0..64).map(|_| rng.below(cfg.vocab)).collect();
        let profiles: Vec<ActivationProfile> = DenseForward::new(&model)
            .capture_hidden(&calib)
            .iter()
            .map(|h| ActivationProfile::from_hidden(h, 16))
            .collect();
        let conv =
            convert_model(&model, &profiles, &"S2A2E8".parse().unwrap(), &ConvertOptions::default())
                .unwrap();
        let qconv = quantize_model(&conv.model);
        let logits = DenseForward::new(&qconv).logits(&[1, 2, 3, 4]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }
}
