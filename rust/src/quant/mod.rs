//! Post-training weight quantization substrate (paper §6: "FFN
//! restructuring integrates well with post-training quantization …
//! because the operation preserves layer interfaces").
//!
//! Implements symmetric per-output-channel int8 weight quantization
//! (the W8 setting of AWQ-style PTQ) with simulated dequantized
//! execution, applicable to dense models *and* CMoE-restructured models
//! — the composition test in this module is the §6 claim made
//! executable.

use crate::model::{FfnWeights, LayerFfn, ModelWeights};
use crate::tensor::Tensor;

/// A symmetric int8 per-column quantized matrix.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub shape: Vec<usize>,
    /// One scale per output column (last dim).
    pub scales: Vec<f32>,
    pub data: Vec<i8>,
}

impl QuantizedTensor {
    /// Quantize a 2-D tensor column-wise: `q = round(w / s)`,
    /// `s = max|w_col| / 127`.
    pub fn quantize(w: &Tensor) -> QuantizedTensor {
        assert_eq!(w.rank(), 2);
        let (r, c) = (w.shape[0], w.shape[1]);
        let mut scales = vec![0.0f32; c];
        for i in 0..r {
            for (j, s) in scales.iter_mut().enumerate() {
                *s = s.max(w.at2(i, j).abs());
            }
        }
        for s in scales.iter_mut() {
            *s = if *s > 0.0 { *s / 127.0 } else { 1.0 };
        }
        let mut data = vec![0i8; r * c];
        for i in 0..r {
            for j in 0..c {
                let q = (w.at2(i, j) / scales[j]).round();
                data[i * c + j] = q.clamp(-127.0, 127.0) as i8;
            }
        }
        QuantizedTensor { shape: w.shape.clone(), scales, data }
    }

    /// Dequantize back to f32 (simulated-quantization execution).
    pub fn dequantize(&self) -> Tensor {
        let c = self.shape[1];
        let data = self
            .data
            .iter()
            .enumerate()
            .map(|(k, &q)| q as f32 * self.scales[k % c])
            .collect();
        Tensor::from_vec(data, &self.shape)
    }

    /// Worst-case absolute rounding error of this quantization.
    pub fn max_error_bound(&self) -> f32 {
        self.scales.iter().cloned().fold(0.0, f32::max) * 0.5
    }

    /// Bytes of the quantized representation (int8 + f32 scales).
    pub fn quantized_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }
}

/// Round-trip quantize an FFN (weights replaced by their dequantized
/// int8 versions — interface unchanged, which is the point).
pub fn quantize_ffn(ffn: &FfnWeights) -> FfnWeights {
    FfnWeights {
        w_gate: QuantizedTensor::quantize(&ffn.w_gate).dequantize(),
        w_up: QuantizedTensor::quantize(&ffn.w_up).dequantize(),
        w_down: QuantizedTensor::quantize(&ffn.w_down).dequantize(),
    }
}

/// Quantize every projection of a model (attention + FFN/experts +
/// router + unembedding). Works on dense AND converted models.
pub fn quantize_model(model: &ModelWeights) -> ModelWeights {
    let q = |t: &Tensor| QuantizedTensor::quantize(t).dequantize();
    let mut out = model.clone();
    out.embed = q(&out.embed);
    out.unembed = q(&out.unembed);
    for layer in out.layers.iter_mut() {
        layer.attn.wq = q(&layer.attn.wq);
        layer.attn.wk = q(&layer.attn.wk);
        layer.attn.wv = q(&layer.attn.wv);
        layer.attn.wo = q(&layer.attn.wo);
        match &mut layer.ffn {
            LayerFfn::Dense(f) => *f = quantize_ffn(f),
            LayerFfn::Moe(moe) => {
                moe.shared = quantize_ffn(&moe.shared);
                for e in moe.experts.iter_mut() {
                    *e = quantize_ffn(e);
                }
                if let crate::model::Router::Analytical(rw) = &mut moe.router {
                    rw.w_gate_r = q(&rw.w_gate_r);
                    rw.w_up_r = q(&rw.w_up_r);
                }
            }
        }
    }
    out
}

/// Compression ratio of int8 weights vs f32 for a model's projections.
pub fn compression_ratio() -> f64 {
    // int8 + per-column scale amortized over rows ⇒ ≈ 4×
    4.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::model_config;
    use crate::util::Rng;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let mut rng = Rng::new(501);
        let w = Tensor::randn(&mut rng, &[64, 32], 0.5);
        let q = QuantizedTensor::quantize(&w);
        let back = q.dequantize();
        let err = w.max_abs_diff(&back);
        assert!(err <= q.max_error_bound() + 1e-6, "err {err} > bound {}", q.max_error_bound());
        assert!(err > 0.0, "suspiciously exact");
        // int8 + scales is ~4x smaller
        assert!(q.quantized_bytes() < w.numel() * 4 / 3);
    }

    #[test]
    fn zero_column_is_stable() {
        let mut w = Tensor::zeros(&[4, 3]);
        w.data[0] = 1.0; // col 0 nonzero, col 1/2 all-zero
        let q = QuantizedTensor::quantize(&w);
        let back = q.dequantize();
        assert!(w.max_abs_diff(&back) < 1e-2);
        assert!(back.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantization_composes_with_cmoe() {
        // §6: quantize-then-convert ≈ convert-then-quantize ≈ dense
        use crate::converter::{convert_ffn, reconstruction_error, ConvertOptions};
        use crate::profiling::ActivationProfile;
        let mut rng = Rng::new(502);
        let planted = crate::testutil::structured_ffn(&mut rng, 10, 64, 16, 6);
        let ffn = planted.ffn;
        let x = Tensor::randn(&mut rng, &[256, 10], 1.0);
        let h = crate::tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = ActivationProfile::from_hidden(&h, 12);
        let spec = "S2A4E8".parse().unwrap();

        let moe_fp = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        // convert-then-quantize
        let mut moe_q = moe_fp.clone();
        moe_q.shared = quantize_ffn(&moe_q.shared);
        for e in moe_q.experts.iter_mut() {
            *e = quantize_ffn(e);
        }
        let probe = Tensor::randn(&mut rng, &[128, 10], 1.0);
        let e_fp = reconstruction_error(&ffn, &moe_fp, &probe);
        let e_q = reconstruction_error(&ffn, &moe_q, &probe);
        assert!(
            (e_q - e_fp).abs() < 0.05,
            "quantization changed MoE reconstruction too much: {e_fp:.4} -> {e_q:.4}"
        );
    }

    #[test]
    fn quantized_model_ppl_close_to_fp32() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(503);
        let model = ModelWeights::random(&cfg, &mut rng);
        let qm = quantize_model(&model);
        let toks: Vec<usize> = (0..192).map(|_| rng.below(cfg.vocab)).collect();
        let p_fp = crate::eval::perplexity(&model, &toks, 64);
        let p_q = crate::eval::perplexity(&qm, &toks, 64);
        assert!(
            (p_q / p_fp - 1.0).abs() < 0.05,
            "int8 PPL drift too large: {p_fp:.2} -> {p_q:.2}"
        );
    }

    #[test]
    fn quantize_converted_model_end_to_end() {
        use crate::converter::{convert_model, ConvertOptions};
        use crate::eval::forward::DenseForward;
        use crate::profiling::ActivationProfile;
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(504);
        let model = ModelWeights::random(&cfg, &mut rng);
        let calib: Vec<usize> = (0..64).map(|_| rng.below(cfg.vocab)).collect();
        let profiles: Vec<ActivationProfile> = DenseForward::new(&model)
            .capture_hidden(&calib)
            .iter()
            .map(|h| ActivationProfile::from_hidden(h, 16))
            .collect();
        let conv =
            convert_model(&model, &profiles, &"S2A2E8".parse().unwrap(), &ConvertOptions::default())
                .unwrap();
        let qconv = quantize_model(&conv.model);
        let logits = DenseForward::new(&qconv).logits(&[1, 2, 3, 4]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }
}
