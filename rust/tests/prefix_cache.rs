//! Property suite for `serving::PrefixCache`: the page-granular token
//! trie checked against a brute-force reference over randomized prompt
//! sets, plus the eviction-safety guarantees.
//!
//! * **Lookup == brute force.** For any insertion history, a lookup's
//!   cached-token count equals the longest common full-chunk prefix
//!   with any inserted key (a trie and a max-over-set agree by
//!   construction — this pins the implementation to that spec).
//! * **Page identity.** Chunks shared between insertions resolve to
//!   one physical page; refcounts equal cache holds + simulated slot
//!   mappings at every step.
//! * **Eviction safety.** LRU eviction under page pressure never
//!   releases a page a live slot still maps (refcount > 1), only ever
//!   shrinks lookup results, and frees exactly what it reports.

use cmoe::prop_assert;
use cmoe::runtime::PagePool;
use cmoe::serving::PrefixCache;
use cmoe::util::prop;
use cmoe::util::Rng;

const PAGE_LEN: usize = 2;
const ALPHABET: usize = 3;

/// Brute-force reference: longest shared full-chunk prefix (in tokens)
/// between `q` and any inserted key.
fn brute_force_tokens(inserted: &[Vec<usize>], q: &[usize]) -> usize {
    let mut best = 0usize;
    for key in inserted {
        let mut t = 0;
        while t + PAGE_LEN <= q.len().min(key.len()) && q[t..t + PAGE_LEN] == key[t..t + PAGE_LEN]
        {
            t += PAGE_LEN;
        }
        best = best.max(t);
    }
    best
}

/// Insert `key` the way a prefill does: the "slot" owns freshly
/// allocated pages for its full chunks, the cache retains what it
/// keeps, the slot then releases its own references.
fn insert_as_slot(cache: &mut PrefixCache, pool: &mut PagePool, key: &[usize]) {
    let n = key.len() / PAGE_LEN;
    let pages: Vec<usize> = (0..n).map(|_| pool.try_alloc().expect("unbounded pool")).collect();
    cache.insert(key, &pages, pool);
    for p in pages {
        pool.release(p);
    }
}

#[test]
fn prop_lookup_matches_brute_force_reference() {
    prop::check(
        "prefix-cache lookups equal the brute-force longest-chunk-prefix",
        prop::Config { cases: 220, seed: 0x7A1E5, max_size: 24 },
        |rng: &mut Rng, size| {
            let mut pool = PagePool::new(PAGE_LEN, 2 * PAGE_LEN, None);
            let mut cache = PrefixCache::new(PAGE_LEN);
            let mut inserted: Vec<Vec<usize>> = Vec::new();
            for _ in 0..size {
                // small alphabet so prefixes genuinely collide
                let key: Vec<usize> =
                    (0..rng.below(12)).map(|_| rng.below(ALPHABET)).collect();
                if rng.f32() < 0.6 {
                    insert_as_slot(&mut cache, &mut pool, &key);
                    inserted.push(key.clone());
                }
                let q: Vec<usize> = if rng.f32() < 0.5 && !inserted.is_empty() {
                    // probe near an inserted key: copy + perturb tail
                    let mut q = inserted[rng.below(inserted.len())].clone();
                    if !q.is_empty() && rng.f32() < 0.7 {
                        let i = rng.below(q.len());
                        q[i] = rng.below(ALPHABET);
                    }
                    q
                } else {
                    (0..rng.below(12)).map(|_| rng.below(ALPHABET)).collect()
                };
                let (pages, tokens) = cache.lookup(&q);
                let want = brute_force_tokens(&inserted, &q);
                prop_assert!(
                    tokens == want,
                    "lookup({q:?}) = {tokens} tokens, brute force says {want}"
                );
                prop_assert!(
                    pages.len() * PAGE_LEN == tokens,
                    "page count {} disagrees with token count {tokens}",
                    pages.len()
                );
                // every returned page is live and cache-held
                for &p in &pages {
                    prop_assert!(pool.refcount(p) >= 1, "lookup returned a freed page {p}");
                }
            }
            // cache holds exactly its accounted pages; drain-evict frees them all
            prop_assert!(
                pool.pages_in_use() == cache.cached_pages(),
                "pool {} != cache accounting {}",
                pool.pages_in_use(),
                cache.cached_pages()
            );
            let freed = cache.evict(&mut pool, usize::MAX);
            prop_assert!(
                pool.pages_in_use() == 0 && cache.cached_pages() == 0,
                "evict-all leaked {} pages (freed {freed})",
                pool.pages_in_use()
            );
            Ok(())
        },
    );
}

#[test]
fn prop_eviction_never_touches_live_mappings_and_only_shrinks() {
    prop::check(
        "LRU eviction under pressure spares live-mapped prefixes",
        prop::Config { cases: 200, seed: 0xEV1C7, max_size: 16 },
        |rng: &mut Rng, size| {
            let mut pool = PagePool::new(PAGE_LEN, 2 * PAGE_LEN, None);
            let mut cache = PrefixCache::new(PAGE_LEN);
            let mut inserted: Vec<Vec<usize>> = Vec::new();
            for _ in 0..(1 + size) {
                let key: Vec<usize> =
                    (0..PAGE_LEN * (1 + rng.below(4))).map(|_| rng.below(ALPHABET)).collect();
                insert_as_slot(&mut cache, &mut pool, &key);
                inserted.push(key);
            }
            // a "live slot" maps one cached prefix (retains its pages)
            let mapped_key = inserted[rng.below(inserted.len())].clone();
            let (mapped_pages, mapped_tokens) = cache.lookup(&mapped_key);
            for &p in &mapped_pages {
                pool.retain(p);
            }
            // record pre-eviction lookups for the shrink check
            let pre: Vec<usize> =
                inserted.iter().map(|k| cache.lookup(k).1).collect();
            let before = pool.pages_in_use();
            let need = 1 + rng.below(before.max(1));
            let freed = cache.evict(&mut pool, need);
            prop_assert!(
                pool.pages_in_use() == before - freed,
                "evict freed {} pages but reported {freed}",
                before - pool.pages_in_use()
            );
            // the live mapping is untouched: same pages, same coverage
            let (again_pages, again_tokens) = cache.lookup(&mapped_key);
            prop_assert!(
                again_pages == mapped_pages && again_tokens == mapped_tokens,
                "eviction broke a live-mapped prefix: {again_pages:?} != {mapped_pages:?}"
            );
            for &p in &mapped_pages {
                prop_assert!(
                    pool.refcount(p) == 2,
                    "live-mapped page {p} refcount {} != 2",
                    pool.refcount(p)
                );
            }
            // eviction only shrinks coverage, never invents it
            for (k, &was) in inserted.iter().zip(&pre) {
                let now = cache.lookup(k).1;
                prop_assert!(now <= was, "lookup grew after eviction: {now} > {was} for {k:?}");
            }
            // cleanup: slot releases, then everything is evictable
            for &p in &mapped_pages {
                pool.release(p);
            }
            cache.evict(&mut pool, usize::MAX);
            prop_assert!(pool.pages_in_use() == 0, "leaked pages after drain");
            Ok(())
        },
    );
}

#[test]
fn lru_order_is_respected_among_evictable_leaves() {
    let mut pool = PagePool::new(PAGE_LEN, 2 * PAGE_LEN, None);
    let mut cache = PrefixCache::new(PAGE_LEN);
    insert_as_slot(&mut cache, &mut pool, &[0, 0]);
    insert_as_slot(&mut cache, &mut pool, &[1, 1]);
    insert_as_slot(&mut cache, &mut pool, &[2, 2]);
    // touch [0,0] and [2,2]; [1,1] becomes LRU
    cache.lookup(&[0, 0]);
    cache.lookup(&[2, 2]);
    assert_eq!(cache.evict(&mut pool, 1), 1);
    assert_eq!(cache.lookup(&[1, 1]).1, 0, "LRU leaf must go first");
    assert_eq!(cache.lookup(&[0, 0]).1, PAGE_LEN);
    assert_eq!(cache.lookup(&[2, 2]).1, PAGE_LEN);
}
