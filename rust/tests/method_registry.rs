//! Method-registry parity suite: EVERY registered method (bases and
//! `+cmoe-router` hybrids) must produce a structurally sound MoE model
//! — expert membership an exact permutation of `d_ff` neurons with
//! balanced sizes — that round-trips through save/load bit-exactly.
//! Run explicitly by `scripts/check.sh`.

use cmoe::data::calibration::CalibrationSpec;
use cmoe::eval::forward::DenseForward;
use cmoe::model::{model_config, LayerFfn, ModelWeights, Router};
use cmoe::pipeline::{registry, Pipeline};
use cmoe::util::Rng;

fn fast_calib() -> CalibrationSpec {
    CalibrationSpec { examples: 1, seq: 96, k_a: 12, ..Default::default() }
}

#[test]
fn every_registry_method_partitions_and_roundtrips() {
    let cfg = model_config("tiny").unwrap();
    let mut rng = Rng::new(0x5EED);
    let dense = ModelWeights::random(&cfg, &mut rng);
    let probe: Vec<usize> = (0..10).map(|i| (i * 31) % 256).collect();
    let tmp = std::env::temp_dir().join("cmoe_method_registry");
    std::fs::create_dir_all(&tmp).unwrap();

    let names = registry::names();
    assert!(names.len() >= 7, "registry shrank below the seven-method surface: {names:?}");

    for name in names {
        let method = registry::get(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let spec = method.default_spec;
        let run = Pipeline::from_method(method)
            .spec(spec)
            .calib(fast_calib())
            .run(&dense)
            .unwrap_or_else(|e| panic!("{name}: pipeline failed: {e:#}"));

        // --- partition invariants per layer --------------------------
        let m_size = cfg.d_ff / spec.total;
        for (l, layer) in run.model.layers.iter().enumerate() {
            let LayerFfn::Moe(moe) = &layer.ffn else {
                panic!("{name}: layer {l} not converted");
            };
            assert_eq!(
                moe.covered_neurons(),
                (0..cfg.d_ff).collect::<Vec<_>>(),
                "{name}: layer {l} is not an exact permutation of d_ff neurons"
            );
            assert_eq!(moe.experts.len(), spec.routed(), "{name}: layer {l} expert count");
            assert!(
                moe.experts.iter().all(|e| e.hidden_dim() == m_size),
                "{name}: layer {l} experts are not balanced to {m_size} neurons"
            );
            assert_eq!(
                moe.shared.hidden_dim(),
                spec.shared * m_size,
                "{name}: layer {l} shared expert size"
            );
            // router arity matches the partition
            assert_eq!(moe.router.n_routed(), spec.routed(), "{name}: layer {l} router arity");
            // hybrids and cmoe carry in-expert representatives
            if name == "cmoe" || name.ends_with(registry::CMOE_ROUTER_SUFFIX) {
                assert!(
                    matches!(moe.router, Router::Analytical(_)),
                    "{name}: layer {l} should use the analytical router"
                );
                assert_eq!(moe.representatives.len(), spec.routed());
                for (e, r) in moe.representatives.iter().enumerate() {
                    assert!(
                        moe.expert_neurons[e].contains(r),
                        "{name}: layer {l} representative {r} outside expert {e}"
                    );
                }
            }
        }

        // --- save/load round-trip ------------------------------------
        let path = tmp.join(format!("{}.cmw", name.replace('+', "_")));
        run.model.save(&path).unwrap_or_else(|e| panic!("{name}: save: {e:#}"));
        let back = ModelWeights::load(&path).unwrap_or_else(|e| panic!("{name}: load: {e:#}"));
        let la = DenseForward::new(&run.model).logits(&probe);
        let lb = DenseForward::new(&back).logits(&probe);
        assert_eq!(la.data, lb.data, "{name}: save/load changed the forward pass");
        for (l, (a, b)) in run.model.layers.iter().zip(&back.layers).enumerate() {
            let (LayerFfn::Moe(ma), LayerFfn::Moe(mb)) = (&a.ffn, &b.ffn) else {
                panic!("{name}: layer {l} kind lost in round-trip");
            };
            assert_eq!(ma.expert_neurons, mb.expert_neurons, "{name}: layer {l} bookkeeping");
            assert_eq!(ma.shared_neurons, mb.shared_neurons);
            assert_eq!(ma.representatives, mb.representatives);
            assert_eq!(ma.compensation, mb.compensation, "{name}: layer {l} compensation");
        }
    }
}

#[test]
fn baseline_methods_reject_shared_expert_specs() {
    let cfg = model_config("tiny").unwrap();
    let mut rng = Rng::new(0x5EEE);
    let dense = ModelWeights::random(&cfg, &mut rng);
    for name in ["moefication", "llama-moe", "emoe", "readme"] {
        let err = Pipeline::for_method(name)
            .unwrap()
            .spec("S2A4E8".parse().unwrap())
            .calib(fast_calib())
            .run(&dense);
        assert!(err.is_err(), "{name}: must reject shared-expert specs");
    }
}

#[test]
fn gmoefication_carries_compensation_in_both_router_variants() {
    let cfg = model_config("tiny").unwrap();
    let mut rng = Rng::new(0x5EEF);
    let dense = ModelWeights::random(&cfg, &mut rng);
    for name in ["gmoefication", "gmoefication+cmoe-router"] {
        let run = Pipeline::for_method(name).unwrap().calib(fast_calib()).run(&dense).unwrap();
        for (l, layer) in run.model.layers.iter().enumerate() {
            let LayerFfn::Moe(moe) = &layer.ffn else { panic!() };
            let comp = moe.compensation.as_ref().unwrap_or_else(|| {
                panic!("{name}: layer {l} lost its mean-output compensation")
            });
            assert_eq!(comp.len(), moe.spec.routed());
        }
    }
}
