//! `cmoe lint` rule fixtures: every rule fires on a seeded violation,
//! the inline allowlist suppresses with a written reason (and only
//! with one), the JSON report round-trips through `util::json`, and —
//! the gate itself — the real tree lints clean.
//!
//! The fixtures live in string literals, which the lint lexer strips
//! before any rule runs, so this file cannot pollute the tree-wide
//! self-check it performs. `scripts/mirror_lint.py::self_test` carries
//! the same fixtures for rustc-less images; keep the two in step.

use cmoe::lint::{lint_source, report, rules, Finding};
use cmoe::util::json::Json;
use std::path::Path;

const SERVING: &str = "rust/src/serving/fixture.rs";

fn rules_of(findings: &[Finding]) -> Vec<&str> {
    let mut r: Vec<&str> = findings.iter().map(|f| f.rule.as_str()).collect();
    r.sort_unstable();
    r.dedup();
    r
}

// ---------------------------------------------------------------- clock

#[test]
fn clock_discipline_fires_on_instant_now() {
    let fix = "fn f() { let t = std::time::Instant::now(); }\n";
    let got = lint_source(SERVING, fix);
    assert_eq!(rules_of(&got), ["clock-discipline"], "{got:?}");
    assert_eq!(got[0].line, 1);
}

#[test]
fn clock_discipline_fires_on_system_time() {
    let got = lint_source(SERVING, "fn f() { let t = SystemTime::now(); }\n");
    assert_eq!(rules_of(&got), ["clock-discipline"], "{got:?}");
}

#[test]
fn clock_discipline_silent_in_clock_rs_and_tests() {
    let fix = "fn f() { let t = std::time::Instant::now(); }\n";
    assert!(lint_source("rust/src/serving/clock.rs", fix).is_empty());
    assert!(lint_source("rust/tests/fixture.rs", fix).is_empty());
}

// ---------------------------------------------------------------- panic

#[test]
fn panic_discipline_fires_in_serving_and_runtime() {
    let fix = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n";
    let got = lint_source(SERVING, fix);
    assert_eq!(rules_of(&got), ["panic-discipline"], "{got:?}");
    let got = lint_source("rust/src/runtime/fixture.rs", "fn f() { unreachable!(\"no\") }\n");
    assert_eq!(rules_of(&got), ["panic-discipline"], "{got:?}");
}

#[test]
fn panic_discipline_out_of_scope_and_cfg_test() {
    let fix = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n";
    assert!(lint_source("rust/src/moe/fixture.rs", fix).is_empty());
    let in_tests =
        "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u8>) -> u8 { x.unwrap() }\n}\n";
    assert!(lint_source(SERVING, in_tests).is_empty());
}

// ---------------------------------------------------------- determinism

#[test]
fn determinism_fires_on_hashmap_in_scope_only() {
    let fix = "use std::collections::HashMap;\n";
    let got = lint_source(SERVING, fix);
    assert_eq!(rules_of(&got), ["determinism"], "{got:?}");
    assert!(lint_source("rust/src/util/fixture.rs", fix).is_empty());
}

// ------------------------------------------------------------- hot path

#[test]
fn hot_path_alloc_fires_inside_annotated_fn() {
    let fix = "// lint: hot-path\nfn f() -> Vec<u8> { vec![0u8].to_vec() }\n";
    let got = lint_source("rust/src/moe/fixture.rs", fix);
    assert_eq!(rules_of(&got), ["hot-path-alloc"], "{got:?}");
    assert_eq!(got.len(), 2, "vec![…] and .to_vec(): {got:?}");
}

#[test]
fn hot_path_alloc_silent_without_annotation() {
    let fix = "fn f() -> Vec<u8> { vec![0u8].to_vec() }\n";
    assert!(lint_source("rust/src/moe/fixture.rs", fix).is_empty());
}

// ------------------------------------------------------------ allowlist

#[test]
fn allow_with_reason_suppresses() {
    let fix = "// lint: allow(clock-discipline) — fixture: wall-clock is the point here\n\
               fn f() { let t = std::time::Instant::now(); }\n";
    assert!(lint_source(SERVING, fix).is_empty());
}

#[test]
fn allow_without_reason_is_rejected() {
    let fix = "// lint: allow(clock-discipline)\n\
               fn f() { let t = std::time::Instant::now(); }\n";
    let got = lint_source(SERVING, fix);
    // the violation stays AND the bad directive is its own finding
    assert_eq!(rules_of(&got), [rules::RULE_ALLOW_SYNTAX, "clock-discipline"], "{got:?}");
}

#[test]
fn allow_of_unknown_rule_is_rejected() {
    let got = lint_source(SERVING, "// lint: allow(no-such-rule) — whatever\nfn f() {}\n");
    assert_eq!(rules_of(&got), [rules::RULE_ALLOW_SYNTAX], "{got:?}");
}

// -------------------------------------------------------------- lexing

#[test]
fn string_literals_are_invisible() {
    let fix = "fn f() -> &'static str { \"Instant::now() .unwrap()\" }\n";
    assert!(lint_source(SERVING, fix).is_empty());
}

// ------------------------------------------------------- json reporting

#[test]
fn json_report_round_trips() {
    let fix = "fn f() { let t = std::time::Instant::now(); }\n";
    let findings = lint_source(SERVING, fix);
    assert_eq!(findings.len(), 1);
    let txt = report::render_json(&findings);
    let j = Json::parse(&txt).expect("render_json must emit valid json");
    assert_eq!(j.get("count").as_usize(), Some(1));
    let arr = j.get("findings").as_arr().expect("findings array");
    assert_eq!(arr.len(), 1);
    assert_eq!(arr[0].get("rule").as_str(), Some("clock-discipline"));
    assert_eq!(arr[0].get("path").as_str(), Some(SERVING));
    assert_eq!(arr[0].get("line").as_usize(), Some(1));
    assert_eq!(arr[0].get("message").as_str(), Some(findings[0].message.as_str()));
}

#[test]
fn json_report_escapes_quotes() {
    let f = Finding::new("determinism", "a/b.rs", 3, "bad \"quote\"\n".to_string());
    let j = Json::parse(&report::render_json(&[f])).expect("valid json");
    assert_eq!(j.get("findings").as_arr().unwrap()[0].get("message").as_str(),
        Some("bad \"quote\"\n"));
}

// ------------------------------------------------------ the gate itself

/// The real tree must lint clean — this is the same check
/// `scripts/check.sh` runs via `cmoe lint`, pinned here so a plain
/// `cargo test` catches a violation even when check.sh isn't run.
#[test]
fn real_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("repo root").to_path_buf();
    let findings = cmoe::lint::lint_tree(&root).expect("lint_tree");
    assert!(
        findings.is_empty(),
        "tree has lint findings:\n{}",
        report::render_text(&findings)
    );
}
