//! Preempt/resume property suite (host-only, stub forward): the
//! ISSUE-6 acceptance property that preemption is **token-invisible**.
//!
//! Random mixed-priority traces — deadlines, both [`PreemptMode::Park`]
//! and [`PreemptMode::Drop`], tight pools that force victim selection —
//! must produce, for every request, exactly the token stream of an
//! unpreempted run-to-completion reference (`stub_reference`):
//!
//! * no request is lost, duplicated, or failed;
//! * every preempted request resumes (resumed == preemptions at
//!   drain);
//! * parked KV never recomputes (`preempt_recompute_tokens == 0`
//!   under Park), dropped KV always replays through prefill;
//! * all KV pages and slot contexts are reclaimed when the trace
//!   drains.
//!
//! Deterministic companions pin the policy edges: urgency (not mere
//! priority) is what triggers preemption, and anti-starvation aging
//! bounds how long a Low waits behind a High stream.

use cmoe::prop_assert;
use cmoe::serving::{
    stub_reference, BatcherConfig, Clock, ContinuousSession, GenParams, PreemptMode, Priority,
    Request, StubForward,
};
use cmoe::util::prop;
use cmoe::util::Rng;
use std::collections::VecDeque;
use std::time::Duration;

const VOCAB: usize = 17;

fn random_request(id: u64, rng: &mut Rng) -> Request {
    let prompt: Vec<usize> = (0..1 + rng.below(8)).map(|_| rng.below(VOCAB)).collect();
    let params = GenParams {
        max_new_tokens: 1 + rng.below(12),
        temperature: if rng.f32() < 0.5 { 0.0 } else { 0.8 },
        seed: rng.next_u64(),
        stop_token: if rng.f32() < 0.2 { Some(rng.below(VOCAB)) } else { None },
    };
    let priority = match rng.below(10) {
        0..=2 => Priority::High,
        3..=6 => Priority::Normal,
        _ => Priority::Low,
    };
    let mut r = Request::new(id, prompt, params).with_priority(priority);
    // tight deadlines on the high class are what force preemption
    if priority == Priority::High && rng.f32() < 0.7 {
        r = r.with_deadline_steps(rng.below(3) as u64);
    } else if rng.f32() < 0.2 {
        r = r.with_deadline_steps((2 + rng.below(8)) as u64);
    }
    r
}

fn session(buckets: Vec<usize>, kv_cap: usize, mode: PreemptMode) -> ContinuousSession<StubForward> {
    let pool = *buckets.iter().max().unwrap();
    ContinuousSession::with_clock(
        BatcherConfig {
            buckets,
            max_wait: Duration::ZERO,
            preempt: mode,
            ..Default::default()
        },
        StubForward::new(pool, VOCAB, kv_cap),
        Clock::manual(),
    )
    .unwrap()
}

#[test]
fn prop_preemption_is_token_invisible_in_both_modes() {
    let mut total_preemptions = 0u64;
    prop::check(
        "preempt/resume (park and drop) preserves per-request token streams",
        prop::Config { cases: 80, seed: 0x9EE47, max_size: 24 },
        |rng: &mut Rng, size| {
            for &mode in &[PreemptMode::Park, PreemptMode::Drop] {
                // small pools so urgent Highs actually have to evict
                let buckets = vec![1 + rng.below(3)];
                let kv_cap = 24 + rng.below(32);
                let n_req = 1 + rng.below(size.max(1));
                let mut sess = session(buckets, kv_cap, mode);
                let reqs: Vec<Request> =
                    (0..n_req).map(|i| random_request(i as u64, rng)).collect();
                let mut pending: VecDeque<Request> = reqs.iter().cloned().collect();
                let mut results = Vec::new();
                let mut guard = 0;
                while !(pending.is_empty() && sess.is_idle()) {
                    for _ in 0..rng.below(3) {
                        if let Some(r) = pending.pop_front() {
                            sess.enqueue(r);
                        }
                    }
                    results.extend(sess.step().map_err(|e| e.to_string())?);
                    guard += 1;
                    prop_assert!(guard < 100_000, "preemption trace failed to converge");
                }
                // conservation: every id exactly once, none failed
                let failures = sess.take_failures();
                prop_assert!(failures.is_empty(), "unexpected failures: {failures:?}");
                let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
                ids.sort_unstable();
                ids.dedup();
                prop_assert!(
                    ids.len() == n_req && results.len() == n_req,
                    "lost or duplicated requests: {} results, {} distinct ids, {n_req} sent",
                    results.len(),
                    ids.len()
                );
                // token identity: preemption must be invisible
                for r in &results {
                    let want = stub_reference(&reqs[r.id as usize], VOCAB, kv_cap);
                    prop_assert!(
                        r.tokens == want,
                        "[{mode:?}] request {} diverged after preemption: {:?} != {:?}",
                        r.id,
                        r.tokens,
                        want
                    );
                }
                let m = sess.metrics();
                prop_assert!(m.retired == n_req as u64, "retired {} != {n_req}", m.retired);
                prop_assert!(m.failed == 0 && m.faults_contained == 0, "phantom faults");
                prop_assert!(
                    m.resumed == m.preemptions,
                    "preempted {} but resumed {}: a victim was stranded",
                    m.preemptions,
                    m.resumed
                );
                prop_assert!(
                    m.preempt_parked + m.preempt_dropped == m.preemptions,
                    "preemption mode accounting leaks"
                );
                match mode {
                    PreemptMode::Park => prop_assert!(
                        m.preempt_recompute_tokens == 0,
                        "park mode recomputed {} tokens",
                        m.preempt_recompute_tokens
                    ),
                    PreemptMode::Drop => prop_assert!(
                        m.preemptions == 0 || m.preempt_recompute_tokens > 0,
                        "drop-mode preemption recomputed nothing"
                    ),
                    PreemptMode::Off => unreachable!(),
                }
                total_preemptions += m.preemptions;
                // nothing leaks: contexts and KV pages all reclaimed
                prop_assert!(
                    sess.forward().live_contexts() == 0,
                    "leaked {} slot contexts",
                    sess.forward().live_contexts()
                );
                prop_assert!(
                    sess.forward().kv().pages().pages_in_use() == 0,
                    "leaked {} KV pages",
                    sess.forward().kv().pages().pages_in_use()
                );
            }
            Ok(())
        },
    );
    // the suite must actually exercise the machinery it claims to pin
    assert!(total_preemptions > 0, "no trace ever preempted — property is vacuous");
}

#[test]
fn priority_alone_does_not_preempt_urgency_does() {
    // two Lows saturate the pool; a High WITHOUT a deadline waits its
    // turn (no eviction), while a deadline-0 High evicts immediately
    for (deadline, want_preempt) in [(None, 0u64), (Some(0), 1u64)] {
        let mut sess = session(vec![2], 64, PreemptMode::Park);
        for i in 0..2 {
            sess.enqueue(
                Request::new(
                    i,
                    vec![1, 2, 3],
                    GenParams { max_new_tokens: 10, temperature: 0.0, seed: i, stop_token: None },
                )
                .with_priority(Priority::Low),
            );
        }
        sess.step().unwrap();
        sess.step().unwrap();
        let mut high = Request::new(
            9,
            vec![4, 5],
            GenParams { max_new_tokens: 2, temperature: 0.0, seed: 9, stop_token: None },
        )
        .with_priority(Priority::High);
        if let Some(d) = deadline {
            high = high.with_deadline_steps(d);
        }
        sess.enqueue(high);
        let results = sess.drain().unwrap();
        assert_eq!(results.len(), 3);
        assert_eq!(
            sess.metrics().preemptions,
            want_preempt,
            "deadline {deadline:?} should preempt {want_preempt} victims"
        );
        assert!(sess.take_failures().is_empty());
    }
}

#[test]
fn aging_bounds_low_class_wait_behind_a_high_stream() {
    // pool of 1, a Low enqueued first, then a stream of Highs. With
    // aging at 3 steps the Low overtakes the queued Highs once its
    // front age crosses the threshold; without aging it goes dead last.
    let run = |age_promote_steps: u64| -> Vec<u64> {
        let mut sess = ContinuousSession::with_clock(
            BatcherConfig {
                buckets: vec![1],
                max_wait: Duration::ZERO,
                age_promote_steps,
                ..Default::default()
            },
            StubForward::new(1, VOCAB, 64),
            Clock::manual(),
        )
        .unwrap();
        let g = |seed| GenParams {
            max_new_tokens: 3,
            temperature: 0.0,
            seed,
            stop_token: None,
        };
        sess.enqueue(Request::new(0, vec![1, 2], g(0)).with_priority(Priority::Low));
        // a steady stream of Highs, one arrival per step: class order
        // alone would keep the High queue ahead forever, so only the
        // aging rule can get the older Low in edgewise. Completion
        // order matters here, so step manually (drain sorts by id).
        let mut order = Vec::new();
        for i in 1..=5 {
            sess.enqueue(Request::new(i, vec![3, 4], g(i)).with_priority(Priority::High));
            order.extend(sess.step().unwrap().iter().map(|r| r.id));
        }
        while !sess.is_idle() {
            order.extend(sess.step().unwrap().iter().map(|r| r.id));
        }
        order
    };
    let no_aging = run(u64::MAX);
    assert_eq!(*no_aging.last().unwrap(), 0, "without aging the Low finishes last");
    let aged = run(3);
    let low_pos = aged.iter().position(|&id| id == 0).unwrap();
    assert!(
        low_pos < aged.len() - 1,
        "aging never promoted the starved Low: completion order {aged:?}"
    );
}
