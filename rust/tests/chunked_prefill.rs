//! Chunked-prefill property suite (host-only, stub forward): the
//! ISSUE-9 acceptance properties.
//!
//! Chunked prefill splits a long prompt's prefill across scheduler
//! steps under a per-step token budget so one long prompt cannot
//! freeze live decodes. The suite pins what chunking is — a pure
//! rescheduling of the same compute:
//!
//! * **token-invisible**: for every chunk budget (including 1), with
//!   the prompt-prefix cache on or off, every request emits exactly
//!   the run-to-completion reference stream (`stub_reference`);
//! * **budget-respecting**: no scheduler step prefills more prompt
//!   tokens than the configured budget;
//! * **honest TTFT**: `ttft_steps` stamps at the step the first token
//!   actually samples — the *final* chunk — so an uncontended request
//!   reports exactly `ceil(prompt / budget)` steps; requests aborted
//!   mid-prefill never report a TTFT at all (they land in
//!   `SchedulerMetrics::no_first_token`, keeping percentiles clean);
//! * **leak-free under preemption**: mid-prefill preemption (park and
//!   drop) resumes to the identical stream and reclaims every KV page
//!   and slot context at drain.

use anyhow::Result;
use cmoe::prop_assert;
use cmoe::serving::{
    stub_reference, BatcherConfig, Clock, ContinuousSession, GenParams, PreemptMode,
    PrefillOutcome, Priority, Request, StepForward, StubForward,
};
use cmoe::util::prop;
use cmoe::util::Rng;
use std::collections::VecDeque;
use std::time::Duration;

const VOCAB: usize = 21;
const KV_CAP: usize = 96;

/// Mixed workload: mostly short interactive prompts plus a long-prompt
/// minority — the shape chunking exists for. Prompt + generation stay
/// below `KV_CAP` so capacity retirement never masks a divergence.
fn random_request(id: u64, rng: &mut Rng) -> Request {
    let long = rng.f32() < 0.35;
    let plen = if long { 24 + rng.below(33) } else { 1 + rng.below(8) };
    let prompt: Vec<usize> = (0..plen).map(|_| rng.below(VOCAB)).collect();
    let params = GenParams {
        max_new_tokens: 1 + rng.below(10),
        temperature: if rng.f32() < 0.5 { 0.0 } else { 0.8 },
        seed: rng.next_u64(),
        stop_token: if rng.f32() < 0.2 { Some(rng.below(VOCAB)) } else { None },
    };
    Request::new(id, prompt, params)
}

fn session(
    buckets: Vec<usize>,
    chunk: usize,
    prefix_cache: bool,
    preempt: PreemptMode,
) -> ContinuousSession<StubForward> {
    let pool = *buckets.iter().max().unwrap();
    let fwd = if prefix_cache {
        StubForward::with_prefix_cache(pool, VOCAB, KV_CAP, 4)
    } else {
        StubForward::new(pool, VOCAB, KV_CAP)
    };
    ContinuousSession::with_clock(
        BatcherConfig {
            buckets,
            max_wait: Duration::ZERO,
            prefill_chunk_tokens: chunk,
            preempt,
            ..Default::default()
        },
        fwd,
        Clock::manual(),
    )
    .unwrap()
}

/// Enqueue in random dribbles, step to drain, return results.
fn run(
    sess: &mut ContinuousSession<StubForward>,
    reqs: &[Request],
    rng: &mut Rng,
) -> Result<Vec<cmoe::serving::RequestResult>, String> {
    let mut pending: VecDeque<Request> = reqs.iter().cloned().collect();
    let mut out = Vec::new();
    let mut guard = 0;
    while !(pending.is_empty() && sess.is_idle()) {
        for _ in 0..rng.below(3) {
            if let Some(r) = pending.pop_front() {
                sess.enqueue(r);
            }
        }
        out.extend(sess.step().map_err(|e| e.to_string())?);
        guard += 1;
        if guard >= 100_000 {
            return Err("chunked trace failed to converge".into());
        }
    }
    Ok(out)
}

#[test]
fn prop_chunked_prefill_is_token_invisible_with_and_without_prefix_cache() {
    prop::check(
        "any chunk budget × prefix cache on/off preserves per-request token streams",
        prop::Config { cases: 60, seed: 0xC4E9, max_size: 20 },
        |rng: &mut Rng, size| {
            // budget 0 = monolithic; 1 is the adversarial minimum
            let chunk = *[0usize, 1, 2, 5, 8, 32].get(rng.below(6)).unwrap();
            for &cache in &[false, true] {
                let buckets = vec![1 + rng.below(4)];
                let n_req = 1 + rng.below(size.max(1));
                let reqs: Vec<Request> =
                    (0..n_req).map(|i| random_request(i as u64, rng)).collect();
                let mut sess = session(buckets, chunk, cache, PreemptMode::Off);
                let results = run(&mut sess, &reqs, rng)?;
                prop_assert!(
                    results.len() == n_req && sess.take_failures().is_empty(),
                    "lost requests: {} of {n_req} (chunk {chunk}, cache {cache})",
                    results.len()
                );
                for r in &results {
                    let want = stub_reference(&reqs[r.id as usize], VOCAB, KV_CAP);
                    prop_assert!(
                        r.tokens == want,
                        "request {} diverged at chunk budget {chunk}, cache {cache}: \
                         {:?} != {:?}",
                        r.id,
                        r.tokens,
                        want
                    );
                    prop_assert!(
                        r.ttft.is_some() && r.ttft_steps.is_some(),
                        "served request {} reported no TTFT",
                        r.id
                    );
                }
                let m = sess.metrics();
                prop_assert!(
                    m.retired == n_req as u64 && m.no_first_token == 0,
                    "retired {} / no_first_token {} over {n_req} served",
                    m.retired,
                    m.no_first_token
                );
                // slot hygiene: the only pages still held belong to the
                // prefix cache (none at all when it is off)
                let pages = sess.forward().kv().pages().pages_in_use();
                let cached =
                    sess.forward().page_metrics().map_or(0, |p| p.cached_pages);
                prop_assert!(
                    sess.forward().live_contexts() == 0 && pages == cached,
                    "leaked KV: {pages} pages in use, {cached} cache-held"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_no_step_prefills_past_the_chunk_budget() {
    prop::check(
        "per-step prefilled prompt tokens never exceed the budget",
        prop::Config { cases: 40, seed: 0xB4D6, max_size: 16 },
        |rng: &mut Rng, size| {
            let chunk = 1 + rng.below(24);
            let n_req = 1 + rng.below(size.max(1));
            let reqs: Vec<Request> = (0..n_req).map(|i| random_request(i as u64, rng)).collect();
            let mut sess = session(vec![1 + rng.below(4)], chunk, false, PreemptMode::Off);
            let mut pending: VecDeque<Request> = reqs.iter().cloned().collect();
            let mut prev = 0u64;
            let mut guard = 0;
            while !(pending.is_empty() && sess.is_idle()) {
                for _ in 0..rng.below(3) {
                    if let Some(r) = pending.pop_front() {
                        sess.enqueue(r);
                    }
                }
                sess.step().map_err(|e| e.to_string())?;
                let now = sess.forward().prefilled_tokens;
                prop_assert!(
                    now - prev <= chunk as u64,
                    "step prefilled {} tokens past budget {chunk}",
                    now - prev
                );
                prev = now;
                guard += 1;
                prop_assert!(guard < 100_000, "budget trace failed to converge");
            }
            Ok(())
        },
    );
}

#[test]
fn ttft_steps_counts_to_the_final_chunk_not_the_first() {
    // uncontended long prompt: the first token samples when the LAST
    // chunk lands, so ttft_steps is exactly ceil(plen / budget) —
    // monolithic (budget 0) stays 1
    for (plen, chunk, want) in
        [(40usize, 0usize, 1u64), (40, 40, 1), (40, 16, 3), (40, 1, 40), (7, 3, 3), (1, 1, 1)]
    {
        let mut sess = session(vec![1], chunk, false, PreemptMode::Off);
        let prompt: Vec<usize> = (0..plen).map(|j| j % VOCAB).collect();
        sess.enqueue(Request::new(
            0,
            prompt,
            GenParams { max_new_tokens: 3, temperature: 0.0, seed: 7, stop_token: None },
        ));
        let results = sess.drain().unwrap();
        assert_eq!(
            results[0].ttft_steps,
            Some(want),
            "plen {plen} at budget {chunk} must stamp TTFT at the final chunk"
        );
    }
}

#[test]
fn aborted_mid_prefill_requests_report_no_ttft_and_count_separately() {
    // budget 1 over a 24-token prompt: after 3 steps the request is
    // mid-prefill with no first token; aborting it must increment
    // no_first_token (so TTFT percentiles exclude it) and free its KV
    let mut sess = session(vec![2], 1, false, PreemptMode::Off);
    let long: Vec<usize> = (0..24).map(|j| j % VOCAB).collect();
    sess.enqueue(Request::new(
        0,
        long,
        GenParams { max_new_tokens: 4, temperature: 0.0, seed: 1, stop_token: None },
    ));
    for _ in 0..3 {
        let done = sess.step().unwrap();
        assert!(done.is_empty(), "24-token prompt finished within 3 one-token chunks");
    }
    let ids = sess.abort_all();
    assert_eq!(ids, vec![0]);
    assert_eq!(sess.metrics().no_first_token, 1, "mid-prefill abort must be counted");
    assert_eq!(sess.forward().live_contexts(), 0, "aborted slot context leaked");
    assert_eq!(sess.forward().kv().pages().pages_in_use(), 0, "aborted KV pages leaked");
}

#[test]
fn shed_requests_produce_no_result_and_no_ttft_sample() {
    // bounded admission with no degrade margin: overflow is shed at
    // enqueue and must never surface as a (zero-TTFT) result
    let pool = 1;
    let mut sess = ContinuousSession::with_clock(
        BatcherConfig {
            buckets: vec![pool],
            max_wait: Duration::ZERO,
            prefill_chunk_tokens: 2,
            queue_cap: Some(2),
            degrade_margin: 0,
            ..Default::default()
        },
        StubForward::new(pool, VOCAB, KV_CAP),
        Clock::manual(),
    )
    .unwrap();
    for i in 0..6u64 {
        sess.enqueue(Request::new(
            i,
            vec![1, 2, 3, 4, 5],
            GenParams { max_new_tokens: 2, temperature: 0.0, seed: i, stop_token: None },
        ));
    }
    let shed = sess.metrics().shed_requests;
    assert!(shed > 0, "queue cap 2 never shed out of 6 arrivals");
    let results = sess.drain().unwrap();
    assert_eq!(results.len(), 6 - shed as usize);
    assert!(results.iter().all(|r| r.ttft.is_some() && r.ttft_steps.is_some()));
}

#[test]
fn prop_savings_meter_reconciles_to_total_prompt_tokens() {
    // ISSUE-10 metering invariant: every admitted-and-served prompt
    // token is metered exactly once, as either computed
    // (`prefill_tokens`) or genuinely skipped (`prefill_tokens_saved`)
    // — across chunk budgets, prefix cache on/off, and preemption
    // modes (drop-preempt recompute is metered separately and must not
    // disturb the sum)
    prop::check(
        "prefill_tokens + prefill_tokens_saved == total served prompt tokens",
        prop::Config { cases: 40, seed: 0x5A7E, max_size: 14 },
        |rng: &mut Rng, size| {
            for &mode in &[PreemptMode::Off, PreemptMode::Park, PreemptMode::Drop] {
                for &cache in &[false, true] {
                    let chunk = *[0usize, 1, 4, 16].get(rng.below(4)).unwrap();
                    let n_req = 1 + rng.below(size.max(1));
                    let reqs: Vec<Request> = (0..n_req)
                        .map(|i| {
                            let mut r = random_request(i as u64, rng);
                            if mode != PreemptMode::Off && rng.f32() < 0.3 {
                                r = r
                                    .with_priority(Priority::High)
                                    .with_deadline_steps(rng.below(3) as u64);
                            }
                            // duplicate prompts: give the prefix cache
                            // real overlap to claim savings on
                            if i > 0 && rng.f32() < 0.4 {
                                r.prompt = shared_prefix_prompt(i, rng);
                            }
                            r
                        })
                        .collect();
                    let mut sess = session(vec![1 + rng.below(3)], chunk, cache, mode);
                    let results = run(&mut sess, &reqs, rng)?;
                    prop_assert!(results.len() == n_req, "lost requests");
                    let total: u64 = reqs.iter().map(|r| r.prompt.len() as u64).sum();
                    let m = sess.metrics();
                    prop_assert!(
                        m.prefill_tokens + m.prefill_tokens_saved == total,
                        "[{mode:?} cache={cache} chunk={chunk}] metered {} computed + {} \
                         saved != {total} prompt tokens",
                        m.prefill_tokens,
                        m.prefill_tokens_saved
                    );
                    prop_assert!(
                        cache || m.prefill_tokens_saved == 0,
                        "cache-less run claimed {} saved tokens",
                        m.prefill_tokens_saved
                    );
                }
            }
            Ok(())
        },
    );
}

/// A prompt overlapping earlier traffic: repeat a shared page-aligned
/// prefix so the prefix cache has something to map.
fn shared_prefix_prompt(i: usize, rng: &mut Rng) -> Vec<usize> {
    let mut p: Vec<usize> = (0..12).map(|j| (j * 5 + 3) % VOCAB).collect();
    p.extend((0..1 + rng.below(6)).map(|_| (i + rng.below(VOCAB)) % VOCAB));
    p
}

/// A [`StubForward`] wrapper that simulates the engine's monolithic
/// prefill fallback: the prefix cache maps a prefix (and the session
/// provisionally credits it to `prefill_tokens_saved`), but the
/// compute plan starts from position 0 anyway — reported honestly via
/// `PrefillOutcome::start = 0`. The scheduler must reclaim the
/// provisional credit, or the savings meter over-claims (the ISSUE-10
/// bug).
struct MonoFallback(StubForward);

impl StepForward for MonoFallback {
    fn map_prefix(&mut self, slot: usize, prompt: &[usize]) -> Result<Option<usize>> {
        self.0.map_prefix(slot, prompt)
    }

    fn prefill(
        &mut self,
        slots: &[usize],
        prompts: &[&[usize]],
        cached: &[usize],
    ) -> Result<Vec<PrefillOutcome>> {
        let mut out = self.0.prefill(slots, prompts, cached)?;
        for o in out.iter_mut() {
            o.start = 0; // recomputed the overlap: no tokens were skipped
        }
        Ok(out)
    }

    fn decode(
        &mut self,
        slots: &[usize],
        tokens: &[i32],
        pos: &[usize],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        self.0.decode(slots, tokens, pos, bucket)
    }

    fn release(&mut self, slot: usize) {
        self.0.release(slot)
    }

    fn kv_capacity(&self) -> usize {
        self.0.kv_capacity()
    }
}

#[test]
fn monolithic_fallback_recompute_reclaims_the_savings_credit() {
    let prompt: Vec<usize> = (0..16).map(|j| j % VOCAB).collect();
    let params = GenParams { max_new_tokens: 3, temperature: 0.0, seed: 9, stop_token: None };
    let cfg = || BatcherConfig {
        buckets: vec![1],
        max_wait: Duration::ZERO,
        prefill_chunk_tokens: 0, // monolithic prefill
        ..Default::default()
    };

    // honest backend: the second identical prompt maps its prefix and
    // the savings meter keeps the claim (outcome.start == cached)
    let mut honest = ContinuousSession::with_clock(
        cfg(),
        StubForward::with_prefix_cache(1, VOCAB, KV_CAP, 4),
        Clock::manual(),
    )
    .unwrap();
    for id in 0..2u64 {
        honest.enqueue(Request::new(id, prompt.clone(), params));
        honest.drain().unwrap();
    }
    let hm = honest.metrics();
    assert!(hm.prefill_tokens_saved > 0, "prefix cache never claimed a saving");
    assert_eq!(hm.prefill_tokens + hm.prefill_tokens_saved, 2 * prompt.len() as u64);

    // monolithic-fallback backend: same traffic, but the plan
    // recomputes from 0 — every provisional saving must be paid back
    let mut mono = ContinuousSession::with_clock(
        cfg(),
        MonoFallback(StubForward::with_prefix_cache(1, VOCAB, KV_CAP, 4)),
        Clock::manual(),
    )
    .unwrap();
    let mut tokens = Vec::new();
    for id in 0..2u64 {
        mono.enqueue(Request::new(id, prompt.clone(), params));
        tokens.extend(mono.drain().unwrap());
    }
    let mm = mono.metrics();
    assert_eq!(
        mm.prefill_tokens_saved, 0,
        "recomputed overlap still claimed as saved — the over-claiming bug is back"
    );
    assert_eq!(mm.prefill_tokens, 2 * prompt.len() as u64);
    // the reclaim is metering-only: token streams are untouched
    let want = stub_reference(&Request::new(0, prompt.clone(), params), VOCAB, KV_CAP);
    assert!(tokens.iter().all(|r| r.tokens == want), "reclaim changed decode output");
}

#[test]
fn prop_mid_prefill_preemption_leaks_nothing_and_stays_token_identical() {
    let mut total_preemptions = 0u64;
    prop::check(
        "preempting chunked prefills (park and drop) is token-invisible and leak-free",
        prop::Config { cases: 50, seed: 0x9C47, max_size: 16 },
        |rng: &mut Rng, size| {
            for &mode in &[PreemptMode::Park, PreemptMode::Drop] {
                // tiny pool + tiny budget: long prompts spend many
                // steps mid-prefill, where urgent Highs land on them
                let chunk = 1 + rng.below(4);
                let n_req = 1 + rng.below(size.max(1));
                let mut sess = session(vec![1 + rng.below(2)], chunk, false, mode);
                let reqs: Vec<Request> = (0..n_req)
                    .map(|i| {
                        let mut r = random_request(i as u64, rng);
                        if rng.f32() < 0.3 {
                            r = r.with_priority(Priority::High).with_deadline_steps(
                                rng.below(3) as u64,
                            );
                        } else if rng.f32() < 0.3 {
                            r = r.with_priority(Priority::Low);
                        }
                        r
                    })
                    .collect();
                let results = run(&mut sess, &reqs, rng)?;
                prop_assert!(
                    results.len() == n_req && sess.take_failures().is_empty(),
                    "[{mode:?}] lost requests: {} of {n_req}",
                    results.len()
                );
                for r in &results {
                    let want = stub_reference(&reqs[r.id as usize], VOCAB, KV_CAP);
                    prop_assert!(
                        r.tokens == want,
                        "[{mode:?}] request {} diverged after mid-prefill preemption",
                        r.id
                    );
                }
                let m = sess.metrics();
                prop_assert!(m.resumed == m.preemptions, "a preempted request was stranded");
                total_preemptions += m.preemptions;
                prop_assert!(
                    sess.forward().live_contexts() == 0
                        && sess.forward().kv().pages().pages_in_use() == 0,
                    "[{mode:?}] leaked KV after preempted chunked prefills"
                );
            }
            Ok(())
        },
    );
    assert!(total_preemptions > 0, "no trace ever preempted — property is vacuous");
}
