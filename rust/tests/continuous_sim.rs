//! Deterministic seeded-trace simulation of the continuous-batching
//! engine (host-only, stub forward — no artifacts).
//!
//! Each trace fixes arrival steps and heterogeneous request shapes
//! (`max_new_tokens`, `stop_token`, temperature, prompt length); the
//! session replays it step by step. Because the stub model's logits
//! depend only on a request's own context, the run-to-completion
//! reference (`stub_reference`) is exactly what a correct scheduler
//! must emit per request — any admission/retirement/bucket bug shows
//! up as token divergence. The suite also pins the no-starvation
//! bound: FIFO admission means a request waits at most the serialized
//! work of the requests enqueued before it.

use cmoe::serving::{
    stub_reference, BatcherConfig, ContinuousSession, GenParams, Request, RequestResult,
    SchedulerMetrics, StepForward, StubForward,
};
use std::time::Duration;

const VOCAB: usize = 19;

struct Trace {
    arrivals: Vec<(u64, Request)>, // (arrival step, request), ascending
    buckets: Vec<usize>,
    kv_cap: usize,
}

/// Replay a trace: enqueue every request whose arrival step has come,
/// then run one scheduler step; repeat until drained. Returns results
/// in completion order.
fn run_trace(t: &Trace) -> Vec<RequestResult> {
    let pool = *t.buckets.iter().max().unwrap();
    let mut sess = ContinuousSession::new(
        BatcherConfig { buckets: t.buckets.clone(), max_wait: Duration::ZERO, ..Default::default() },
        StubForward::new(pool, VOCAB, t.kv_cap),
    )
    .unwrap();
    let mut next = 0;
    let mut out = Vec::new();
    while next < t.arrivals.len() || !sess.is_idle() {
        while next < t.arrivals.len() && t.arrivals[next].0 <= sess.step_index() {
            sess.enqueue(t.arrivals[next].1.clone());
            next += 1;
        }
        out.extend(sess.step().expect("stub step cannot fail"));
        assert!(sess.step_index() < 1_000_000, "trace failed to converge");
    }
    out
}

fn req(id: u64, prompt_len: usize, p: GenParams) -> Request {
    let prompt = (0..prompt_len).map(|j| (id as usize * 13 + j * 5) % VOCAB).collect();
    Request::new(id, prompt, p)
}

/// The fixed seeded trace the acceptance criterion names: mixed
/// prompt/generation lengths, stop tokens, temperatures, staggered
/// arrivals over a {1, 4} bucket ladder.
fn mixed_trace() -> Trace {
    let g = |max_new, seed, stop, temperature| GenParams {
        max_new_tokens: max_new,
        temperature,
        seed,
        stop_token: stop,
    };
    Trace {
        arrivals: vec![
            (0, req(0, 6, g(24, 11, None, 0.0))),
            (0, req(1, 2, g(3, 12, None, 0.0))),
            (0, req(2, 9, g(16, 13, Some(7), 0.0))),
            (1, req(3, 4, g(1, 14, None, 0.7))),
            (2, req(4, 5, g(40, 15, Some(2), 0.9))),
            (2, req(5, 1, g(8, 16, None, 0.0))),
            (7, req(6, 3, g(12, 17, Some(0), 0.5))),
            (7, req(7, 7, g(5, 18, None, 0.0))),
            (20, req(8, 2, g(6, 19, None, 0.0))),
        ],
        buckets: vec![1, 4],
        kv_cap: 64,
    }
}

#[test]
fn seeded_trace_is_token_identical_to_reference() {
    let t = mixed_trace();
    let results = run_trace(&t);
    assert_eq!(results.len(), t.arrivals.len());
    for r in &results {
        let want_req = &t.arrivals.iter().find(|(_, q)| q.id == r.id).unwrap().1;
        let want = stub_reference(want_req, VOCAB, t.kv_cap);
        assert_eq!(
            r.tokens, want,
            "request {} under continuous batching diverged from run-to-completion",
            r.id
        );
        assert!(!r.tokens.is_empty());
        assert!(r.tokens.len() <= want_req.params.max_new_tokens);
        if let Some(stop) = want_req.params.stop_token {
            if let Some(i) = r.tokens.iter().position(|&x| x == stop) {
                assert_eq!(i, r.tokens.len() - 1, "generation continued past the stop token");
            }
        }
    }
}

#[test]
fn replaying_the_trace_is_bit_deterministic() {
    let t = mixed_trace();
    let a = run_trace(&t);
    let b = run_trace(&t);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id, "completion order must replay exactly");
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.queued_steps, y.queued_steps);
    }
}

#[test]
fn short_requests_overtake_a_long_neighbor() {
    // pool of 2: A (40 tokens) occupies one slot; B/C/D (2 tokens
    // each) stream through the other — early retirement + backfill,
    // which the run-to-completion wave engine cannot do
    let g = |max_new, seed| GenParams {
        max_new_tokens: max_new,
        temperature: 0.0,
        seed,
        stop_token: None,
    };
    let t = Trace {
        arrivals: vec![
            (0, req(0, 4, g(40, 1))),
            (0, req(1, 4, g(2, 2))),
            (3, req(2, 4, g(2, 3))),
            (6, req(3, 4, g(2, 4))),
        ],
        buckets: vec![1, 2],
        kv_cap: 128,
    };
    let order: Vec<u64> = run_trace(&t).iter().map(|r| r.id).collect();
    assert_eq!(order, vec![1, 2, 3, 0], "short requests must finish before the long one");
}

#[test]
fn no_starvation_fifo_bound_holds() {
    // 12 requests with mixed lengths hammer a 2-slot pool; FIFO
    // admission bounds each request's queue wait by the serialized
    // work of the requests enqueued before it: Σ_{j<i} (len_j + 1)
    // steps (each predecessor holds a slot for len_j steps, +1 for
    // the retire→admit boundary). The pool can only shrink the wait.
    let g = |max_new, seed| GenParams {
        max_new_tokens: max_new,
        temperature: 0.0,
        seed,
        stop_token: None,
    };
    let lens = [30usize, 3, 14, 1, 9, 22, 2, 5, 17, 1, 8, 4];
    let t = Trace {
        arrivals: lens
            .iter()
            .enumerate()
            .map(|(i, &len)| ((i as u64) / 3, req(i as u64, 3, g(len, 100 + i as u64))))
            .collect(),
        buckets: vec![1, 2],
        kv_cap: 256,
    };
    let results = run_trace(&t);
    assert_eq!(results.len(), lens.len());
    // the pool (2) is oversubscribed from step 0 (3 arrivals), so the
    // trace must actually exercise queueing
    assert!(results.iter().any(|r| r.queued_steps > 0), "trace never queued anyone");
    // actual generated length of predecessor j (== lens[j] here: no
    // stop tokens and kv_cap is roomy)
    let gen_len: Vec<u64> = (0..lens.len())
        .map(|j| {
            stub_reference(&t.arrivals[j].1, VOCAB, t.kv_cap).len() as u64
        })
        .collect();
    for r in &results {
        let i = r.id as usize;
        let bound: u64 = (0..i).map(|j| gen_len[j] + 1).sum();
        assert!(
            r.queued_steps <= bound,
            "request {i} waited {} steps, FIFO bound is {bound}",
            r.queued_steps
        );
    }
    // FIFO order: admission step (arrival + wait) never decreases in
    // enqueue order
    let mut adm: Vec<(u64, u64)> = results
        .iter()
        .map(|r| (r.id, t.arrivals[r.id as usize].0 + r.queued_steps))
        .collect();
    adm.sort_unstable();
    for w in adm.windows(2) {
        assert!(w[0].1 <= w[1].1, "admission out of FIFO order: {adm:?}");
    }
}

/// Trace whose prompts share two 16-token "system prompts" (page_len 4
/// → 4 full shareable pages each) plus 1–3 unique suffix tokens — the
/// prefix-cache workload. Suffixes stay below a page so the cache only
/// ever holds the genuinely shared system pages.
fn shared_prefix_trace() -> Trace {
    let sys: [Vec<usize>; 2] = [
        (0..16).map(|j| (j * 3 + 1) % VOCAB).collect(),
        (0..16).map(|j| (j * 5 + 2) % VOCAB).collect(),
    ];
    let g = |max_new, seed| GenParams {
        max_new_tokens: max_new,
        temperature: 0.0,
        seed,
        stop_token: None,
    };
    let mut arrivals: Vec<(u64, Request)> = (0..12u64)
        .map(|i| {
            let mut prompt = sys[(i % 2) as usize].clone();
            prompt.extend((0..1 + i as usize % 3).map(|j| (i as usize * 7 + j) % VOCAB));
            (i / 3, Request::new(i, prompt, g(2 + i as usize % 6, 40 + i)))
        })
        .collect();
    // two requests whose prompt IS a bare system prompt: the cache
    // covers the whole prompt, so re-running the last prompt position
    // (its logits seed the first sample) writes into a shared page —
    // the copy-on-write path, exercised end to end
    for (k, i) in [(0usize, 12u64), (1, 13)] {
        arrivals.push((4, Request::new(i, sys[k].clone(), g(3, 40 + i))));
    }
    Trace { arrivals, buckets: vec![1, 4], kv_cap: 64 }
}

/// Replay `shared_prefix_trace` with the prefix cache on or off,
/// returning per-request tokens (by id), the scheduler gauges, the
/// stub's own prefill meter, and (page high-water, COW copies).
fn run_shared_prefix(
    t: &Trace,
    prefix: bool,
) -> (Vec<Vec<usize>>, SchedulerMetrics, u64, (u64, u64)) {
    let pool = *t.buckets.iter().max().unwrap();
    let fwd = if prefix {
        StubForward::with_prefix_cache(pool, VOCAB, t.kv_cap, 4)
    } else {
        StubForward::new(pool, VOCAB, t.kv_cap)
    };
    let mut sess = ContinuousSession::new(
        BatcherConfig { buckets: t.buckets.clone(), max_wait: Duration::ZERO, ..Default::default() },
        fwd,
    )
    .unwrap();
    let mut next = 0;
    let mut tokens = vec![Vec::new(); t.arrivals.len()];
    while next < t.arrivals.len() || !sess.is_idle() {
        while next < t.arrivals.len() && t.arrivals[next].0 <= sess.step_index() {
            sess.enqueue(t.arrivals[next].1.clone());
            next += 1;
        }
        for r in sess.step().expect("stub step cannot fail") {
            tokens[r.id as usize] = r.tokens;
        }
    }
    let pm = sess.forward().page_metrics().expect("stub owns pages");
    let prefilled = sess.forward().prefilled_tokens;
    (tokens, sess.metrics().clone(), prefilled, (pm.high_water_pages as u64, pm.cow_copies))
}

#[test]
fn shared_prefix_cache_is_token_invisible_and_saves_prefill() {
    // the prefix cache is a memory/compute optimization, never a
    // semantic one: per-request tokens must be bit-identical with the
    // cache on vs off, while the prefill-token meter strictly drops
    let t = shared_prefix_trace();
    let (toks_off, m_off, fill_off, (_, cow_off)) = run_shared_prefix(&t, false);
    let (toks_on, m_on, fill_on, (_, cow_on)) = run_shared_prefix(&t, true);
    assert_eq!(cow_off, 0, "no sharing, no COW");
    assert!(cow_on > 0, "bare-system-prompt requests must exercise copy-on-write");
    for (i, (a, b)) in toks_off.iter().zip(&toks_on).enumerate() {
        assert_eq!(a, b, "request {i}: sharing changed the token stream");
        let want = stub_reference(&t.arrivals[i].1, VOCAB, t.kv_cap);
        assert_eq!(*a, want, "request {i} diverged from the run-to-completion reference");
    }
    // accounting: both paths saw the same prompts; sharing converted
    // part of the prefill into page mapping, token for token
    assert_eq!(m_off.prefix_hits, 0);
    assert_eq!(m_off.prefill_tokens_saved, 0);
    assert!(m_on.prefix_hits > 0, "shared-prefix trace never hit the cache");
    assert!(
        m_on.prefill_tokens < m_off.prefill_tokens,
        "sharing did not reduce prefilled tokens: {} vs {}",
        m_on.prefill_tokens,
        m_off.prefill_tokens
    );
    assert_eq!(
        m_on.prefill_tokens + m_on.prefill_tokens_saved,
        m_off.prefill_tokens,
        "prefill accounting must conserve prompt tokens"
    );
    // the session meter agrees with the stub's ground-truth write count
    assert_eq!(fill_off, m_off.prefill_tokens);
    assert_eq!(fill_on, m_on.prefill_tokens);
}

#[test]
fn shared_prefix_replay_is_bit_deterministic_and_dedupes_pages() {
    let t = shared_prefix_trace();
    let (a, am, _, (a_hw, a_cow)) = run_shared_prefix(&t, true);
    let (b, bm, _, (b_hw, b_cow)) = run_shared_prefix(&t, true);
    assert_eq!(a, b, "cache-on replay must be bit-deterministic");
    assert_eq!(am.prefill_tokens, bm.prefill_tokens);
    assert_eq!((a_hw, a_cow), (b_hw, b_cow), "page accounting must replay exactly");
    // and sharing keeps fewer pages resident than the unshared run
    let (_, _, _, (off_hw, _)) = run_shared_prefix(&t, false);
    assert!(
        a_hw < off_hw,
        "page high-water did not drop under sharing: {a_hw} vs {off_hw}"
    );
}

#[test]
fn queue_wait_metrics_match_trace_shape() {
    let t = mixed_trace();
    let pool = *t.buckets.iter().max().unwrap();
    let mut sess = ContinuousSession::new(
        BatcherConfig { buckets: t.buckets.clone(), max_wait: Duration::ZERO, ..Default::default() },
        StubForward::new(pool, VOCAB, t.kv_cap),
    )
    .unwrap();
    let mut next = 0;
    let mut results = Vec::new();
    while next < t.arrivals.len() || !sess.is_idle() {
        while next < t.arrivals.len() && t.arrivals[next].0 <= sess.step_index() {
            sess.enqueue(t.arrivals[next].1.clone());
            next += 1;
        }
        results.extend(sess.step().unwrap());
    }
    let m = sess.metrics();
    assert_eq!(m.admitted, t.arrivals.len() as u64);
    assert_eq!(m.retired, t.arrivals.len() as u64);
    assert_eq!(m.queue_wait_ms.len(), t.arrivals.len());
    assert!(m.peak_live <= pool);
    assert!(m.occupancy() > 0.0 && m.occupancy() <= 1.0);
    // 9 requests through a 4-slot pool: at least 5 admissions must
    // have recycled a retired slot (mid-flight backfill happened)
    assert!(m.slot_reuses >= 5, "slot reuses: {}", m.slot_reuses);
    assert_eq!(results.len(), t.arrivals.len());
}
