//! Dynamic-k property suite: serve-time per-token expert counts
//! pinned against the fixed-k oracle (ROADMAP item 4, the test half).
//!
//! Three groups of properties over randomized converted layers:
//!
//! * **threshold = 0 is the fixed path, bit for bit** — routing
//!   decisions, the grouped CSR, and the full MoE forward all compare
//!   exactly (`==` on every f32) against the pre-dynamic entry points
//!   over ≥ 200 randomized layers/batches;
//! * **any threshold is well-formed** — every token's k lands in
//!   `[k_min, k_max]` (k_max shrunk by per-row tier caps when
//!   present), selected experts are a prefix of the fixed ranking,
//!   gates recompute from the emitted scores, and the CSR is an exact
//!   permutation of the decision list's (token, expert, gate) triples
//!   — including empty-expert and all-tokens-on-one-expert edges;
//! * **monotonicity** — raising the entropy threshold never increases
//!   the total routed rows of a batch.

use cmoe::converter::{convert_ffn, ConvertOptions};
use cmoe::model::{FfnWeights, MoeLayerWeights, MoeSpec};
use cmoe::moe::{
    k_for_ratio, moe_ffn_forward, moe_ffn_forward_dynamic, normalized_entropy, route_tokens,
    route_tokens_dynamic, DynamicK, GroupedRouting,
};
use cmoe::profiling::ActivationProfile;
use cmoe::prop_assert;
use cmoe::tensor::{self, Tensor};
use cmoe::util::{prop, Rng};

const D: usize = 16;
const D_H: usize = 64;
const SPECS: &[&str] = &["S1A2E4", "S2A2E4", "S1A3E8", "S2A3E8", "S3A3E8", "S1A4E8"];

/// Random converted layer: the same dense→MoE recipe the unit tests
/// use, plus randomized gate bias/scale so ranking and gating are both
/// exercised away from their converter defaults.
fn random_layer(rng: &mut Rng) -> (MoeLayerWeights, MoeSpec) {
    let ffn = FfnWeights {
        w_gate: Tensor::randn(rng, &[D, D_H], 0.4),
        w_up: Tensor::randn(rng, &[D, D_H], 0.4),
        w_down: Tensor::randn(rng, &[D_H, D], 0.4),
    };
    let x = Tensor::randn(rng, &[64, D], 1.0);
    let h = tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
    let prof = ActivationProfile::from_hidden(&h, 8);
    let spec: MoeSpec = SPECS[rng.below(SPECS.len())].parse().unwrap();
    let mut moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
    if rng.f32() < 0.5 {
        for b in moe.gate_bias.iter_mut() {
            *b = rng.normal() * 0.1;
        }
    }
    if rng.f32() < 0.5 {
        for u in moe.gate_scale.iter_mut() {
            *u = rng.normal().abs();
        }
    }
    (moe, spec)
}

/// The (token, expert, gate) triples of a decision list, sorted — the
/// canonical multiset both layouts must agree on.
fn triples(dec: &[cmoe::moe::GateDecision]) -> Vec<(usize, usize, u32)> {
    let mut out: Vec<(usize, usize, u32)> = dec
        .iter()
        .enumerate()
        .flat_map(|(t, d)| {
            d.experts.iter().zip(&d.gates).map(move |(&e, &g)| (t, e, g.to_bits()))
        })
        .collect();
    out.sort_unstable();
    out
}

/// The CSR's (token, expert, gate) triples, sorted.
fn csr_triples(r: &GroupedRouting) -> Vec<(usize, usize, u32)> {
    let mut out = Vec::with_capacity(r.total_rows());
    for e in 0..r.n_experts() {
        for row in r.expert_rows(e) {
            out.push((r.token_idx()[row], e, r.gates()[row].to_bits()));
        }
    }
    out.sort_unstable();
    out
}

#[test]
fn prop_threshold_zero_is_bit_identical_to_fixed() {
    prop::check(
        "threshold 0: routing, CSR and forward equal the fixed-k path bit for bit",
        prop::Config { cases: 200, max_size: 12, seed: 0xD1A0 },
        |rng, size| {
            let (moe, spec) = random_layer(rng);
            let q = 1 + rng.below(size.max(1));
            let x = Tensor::randn(rng, &[q, D], 1.0);
            // any non-positive threshold and any k_min mean "fixed"
            let dk = DynamicK { threshold: 0.0, k_min: 1 + rng.below(spec.active + 2) };
            prop_assert!(!dk.is_active(), "threshold 0 must be inactive");

            // routing: exact equality, field by field
            let fixed = route_tokens(&moe, &x);
            let dynamic = route_tokens_dynamic(&moe, &x, dk, None);
            prop_assert!(fixed.len() == dynamic.len(), "decision count diverged");
            for (t, (a, b)) in fixed.iter().zip(&dynamic).enumerate() {
                prop_assert!(a.experts == b.experts, "experts diverged at token {t}");
                prop_assert!(
                    a.gates.iter().map(|g| g.to_bits()).eq(b.gates.iter().map(|g| g.to_bits())),
                    "gates diverged at token {t}"
                );
                prop_assert!(
                    a.scores.iter().map(|s| s.to_bits()).eq(b.scores.iter().map(|s| s.to_bits())),
                    "scores diverged at token {t}"
                );
            }

            // CSR: identical layout, not just identical multiset
            let n_r = spec.routed();
            let mut ra = GroupedRouting::new(n_r);
            let mut rb = GroupedRouting::new(n_r);
            ra.rebuild(n_r, &fixed);
            rb.rebuild(n_r, &dynamic);
            prop_assert!(ra.total_rows() == rb.total_rows(), "CSR row totals diverged");
            prop_assert!(ra.token_idx() == rb.token_idx(), "CSR token order diverged");
            prop_assert!(
                ra.gates().iter().map(|g| g.to_bits()).eq(rb.gates().iter().map(|g| g.to_bits())),
                "CSR gates diverged"
            );
            for e in 0..n_r {
                prop_assert!(ra.expert_rows(e) == rb.expert_rows(e), "CSR offsets diverged at {e}");
            }

            // forward: bitwise-equal outputs and identical stats
            let (ya, sa) = moe_ffn_forward(&moe, &x);
            let (yb, sb) = moe_ffn_forward_dynamic(&moe, &x, dk, None);
            prop_assert!(
                ya.data.iter().map(|v| v.to_bits()).eq(yb.data.iter().map(|v| v.to_bits())),
                "forward outputs diverged"
            );
            prop_assert!(sa.expert_tokens == sb.expert_tokens, "forward stats diverged");
            Ok(())
        },
    );
}

#[test]
fn prop_any_threshold_bounds_prefix_gates_and_csr_permutation() {
    prop::check(
        "dynamic-k decisions are bounded, prefix-stable, gate-aligned, CSR-permutable",
        prop::Config { cases: 160, max_size: 12, seed: 0xD1A1 },
        |rng, size| {
            let (moe, spec) = random_layer(rng);
            let n_k = spec.active;
            let n_r = spec.routed();
            let q = 1 + rng.below(size.max(1));
            let x = Tensor::randn(rng, &[q, D], 1.0);
            let dk = DynamicK {
                threshold: rng.f32().max(f32::MIN_POSITIVE),
                k_min: 1 + rng.below(n_k),
            };
            let caps: Option<Vec<usize>> = (rng.f32() < 0.5)
                .then(|| (0..q).map(|_| 1 + rng.below(n_k + 2)).collect());

            let fixed = route_tokens(&moe, &x);
            let dynamic = route_tokens_dynamic(&moe, &x, dk, caps.as_deref());
            for (t, d) in dynamic.iter().enumerate() {
                let cap = caps.as_ref().map_or(n_k, |c| c[t].clamp(1, n_k));
                let k_min = dk.k_min.clamp(1, cap);
                let k = d.experts.len();
                prop_assert!(
                    (k_min..=cap).contains(&k),
                    "token {t}: k = {k} outside [{k_min}, {cap}]"
                );
                // prefix stability: the k selected experts are exactly
                // the first k of the fixed-k ranking
                prop_assert!(
                    d.experts == fixed[t].experts[..k.min(fixed[t].experts.len())],
                    "token {t}: selection is not a prefix of the fixed ranking"
                );
                // gates recompute from the emitted scores
                let sp = tensor::softmax(&d.scores);
                for (i, (&e, &g)) in d.experts.iter().zip(&d.gates).enumerate() {
                    let want = 1.0 + sp[e] * moe.gate_scale[e];
                    prop_assert!(
                        g.to_bits() == want.to_bits(),
                        "token {t} slot {i}: gate {g} != recomputed {want}"
                    );
                }
            }

            // CSR ↔ decisions: exact (token, expert, gate) permutation,
            // ragged loads included
            let mut r = GroupedRouting::new(n_r);
            r.rebuild(n_r, &dynamic);
            let total: usize = dynamic.iter().map(|d| d.experts.len()).sum();
            prop_assert!(r.total_rows() == total, "CSR rows != Σ k_t");
            prop_assert!(triples(&dynamic) == csr_triples(&r), "CSR is not a permutation");
            Ok(())
        },
    );
}

#[test]
fn prop_raising_threshold_never_increases_routed_rows() {
    prop::check(
        "total routed rows are non-increasing in the entropy threshold",
        prop::Config { cases: 120, max_size: 10, seed: 0xD1A2 },
        |rng, size| {
            let (moe, _) = random_layer(rng);
            let q = 1 + rng.below(size.max(1));
            let x = Tensor::randn(rng, &[q, D], 1.0);
            let mut thresholds: Vec<f32> =
                (0..5).map(|_| rng.f32()).chain([0.0, 1.0]).collect();
            thresholds.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut prev_rows = usize::MAX;
            for &h in thresholds.iter() {
                let dec =
                    route_tokens_dynamic(&moe, &x, DynamicK { threshold: h, k_min: 1 }, None);
                let rows: usize = dec.iter().map(|d| d.experts.len()).sum();
                prop_assert!(
                    rows <= prev_rows,
                    "threshold {h} routed {rows} rows, more than a lower threshold's {prev_rows}"
                );
                prev_rows = rows;
            }
            Ok(())
        },
    );
}

#[test]
fn empty_batch_and_degenerate_edges() {
    let mut rng = Rng::new(0xD1A3);
    let (moe, spec) = random_layer(&mut rng);

    // q = 0: every entry point returns empty without panicking
    let x0 = Tensor::zeros(&[0, D]);
    assert!(route_tokens_dynamic(&moe, &x0, DynamicK::fixed(), Some(&[])).is_empty());
    let (y0, s0) = moe_ffn_forward_dynamic(
        &moe,
        &x0,
        DynamicK { threshold: 0.5, k_min: 1 },
        None,
    );
    assert_eq!(y0.shape, vec![0, D]);
    assert_eq!(s0.tokens, 0);

    // all tokens forced onto one expert (ragged CSR's empty-expert and
    // hot-expert edges at once): a huge ranking bias pins expert 0
    let mut pinned = moe.clone();
    pinned.gate_bias.iter_mut().for_each(|b| *b = 0.0);
    pinned.gate_bias[0] = 1e6;
    let x = Tensor::randn(&mut rng, &[9, D], 1.0);
    // k_min = 1 with an extreme threshold drives confident tokens to 1
    let dec = route_tokens_dynamic(
        &pinned,
        &x,
        DynamicK { threshold: 1.0, k_min: 1 },
        Some(&vec![1; 9]),
    );
    assert!(dec.iter().all(|d| d.experts == [0]), "cap 1 + bias must pin expert 0");
    let n_r = spec.routed();
    let mut r = GroupedRouting::new(n_r);
    r.rebuild(n_r, &dec);
    assert_eq!(r.count(0), 9);
    for e in 1..n_r {
        assert_eq!(r.count(e), 0, "expert {e} should be empty");
    }

    // tier-cap algebra: the paper's operating points and edge inputs
    assert_eq!(k_for_ratio(1.0, 4), 4);
    assert_eq!(k_for_ratio(0.75, 4), 3);
    assert_eq!(k_for_ratio(0.25, 4), 1);
    assert_eq!(k_for_ratio(0.0, 4), 1);
    assert_eq!(k_for_ratio(f32::NAN, 4), 4);
    assert_eq!(k_for_ratio(2.0, 4), 4);
    assert_eq!(k_for_ratio(0.5, 0), 0);

    // entropy sanity at the policy's decision points
    assert_eq!(normalized_entropy(&[1.0]), 0.0);
    assert!((normalized_entropy(&[0.5, 0.5]) - 1.0).abs() < 1e-6);
}
