//! Expert-storage suite (ISSUE 10): the [`ExpertStore`] contract the
//! grouped dispatcher now runs on, host-only and artifact-free.
//!
//! Three properties pin the tentpole:
//!
//! * **bit-identity**: with every expert `Fp32Resident` (plain slices
//!   or a quant-off [`TieredStore`]), routed output through the
//!   trait-generic dispatcher is f32-bit-identical to the fp32 path —
//!   the trait refactor is invisible until a policy opts in;
//! * **bounded divergence**: the int8 band path's per-token divergence
//!   from fp32 stays inside the gate-weighted composition of each
//!   routed expert's analytic [`QuantizedFfn::divergence_bound`], on
//!   randomized experts, routings, and input scales;
//! * **residency bookkeeping**: [`TieredStore::note_step`] agrees with
//!   an independent shadow model (recomputed EMA + top-cap re-sort) on
//!   every hit/miss/prefetch/demotion count over long drifting traces,
//!   never loses an expert, and keeps exactly `resident_cap` experts
//!   warm.

use cmoe::model::FfnWeights;
use cmoe::moe::{
    ExpertResidency, ExpertStore, ExpertView, GateDecision, GroupedRouting, TieredStore,
    RESIDENCY_EMA_DECAY,
};
use cmoe::prop_assert;
use cmoe::quant::QuantizedFfn;
use cmoe::serving::{DispatchArena, GroupedDispatcher};
use cmoe::tensor::Tensor;
use cmoe::util::{prop, Rng};

fn experts(rng: &mut Rng, n: usize, d: usize, m: usize) -> Vec<FfnWeights> {
    (0..n)
        .map(|_| FfnWeights {
            w_gate: Tensor::randn(rng, &[d, m], 0.5),
            w_up: Tensor::randn(rng, &[d, m], 0.5),
            w_down: Tensor::randn(rng, &[m, d], 0.5),
        })
        .collect()
}

/// Synthetic routing: every token picks 1–2 distinct experts with
/// positive gates (the dispatcher applies the gates; the divergence
/// bound composes over them).
fn random_decisions(rng: &mut Rng, tokens: usize, n_r: usize) -> Vec<GateDecision> {
    (0..tokens)
        .map(|_| {
            let k = 1 + rng.below(2.min(n_r));
            let mut es = Vec::new();
            while es.len() < k {
                let e = rng.below(n_r);
                if !es.contains(&e) {
                    es.push(e);
                }
            }
            let gates = es.iter().map(|_| 0.5 + rng.f32()).collect();
            GateDecision { experts: es, gates, scores: vec![0.0; n_r] }
        })
        .collect()
}

/// Grouped dispatch of `xn` through `store` under `decisions`.
fn dispatch<S: ExpertStore + ?Sized>(
    xn: &Tensor,
    decisions: &[GateDecision],
    store: &S,
    n_r: usize,
    m: usize,
) -> Tensor {
    let d = xn.shape[1];
    let mut routing = GroupedRouting::new(n_r);
    routing.rebuild(n_r, decisions);
    let disp = GroupedDispatcher::new(d, m);
    let mut arena = DispatchArena::new();
    let mut out = Tensor::zeros(&[xn.shape[0], d]);
    disp.forward(xn, &routing, store, &mut arena, &mut out);
    out
}

#[test]
fn prop_all_fp32_resident_paths_are_bit_identical() {
    prop::check(
        "slice, Vec, and quant-off TieredStore dispatch to identical bits",
        prop::Config { cases: 30, seed: 0x51C8, max_size: 12 },
        |rng: &mut Rng, size| {
            let d = 4 + rng.below(12);
            let m = 4 + rng.below(20);
            let n_r = 2 + rng.below(5);
            let tokens = 1 + rng.below(size.max(1) * 2);
            let es = experts(rng, n_r, d, m);
            let decisions = random_decisions(rng, tokens, n_r);
            let xn = Tensor::randn(rng, &[tokens, d], 1.0);

            let y_slice = dispatch(&xn, &decisions, es.as_slice(), n_r, m);
            let y_vec = dispatch(&xn, &decisions, &es, n_r, m);
            let store = TieredStore::new(&es, false, 1 + rng.below(n_r));
            let y_store = dispatch(&xn, &decisions, &store, n_r, m);
            for e in 0..n_r {
                prop_assert!(
                    store.residency(e) == ExpertResidency::Fp32Resident
                        && matches!(store.view(e), ExpertView::Fp32(_)),
                    "quant-off store must be all-Fp32Resident"
                );
            }
            for (a, b) in y_slice.data.iter().zip(&y_vec.data) {
                prop_assert!(a.to_bits() == b.to_bits(), "Vec impl diverged from slice");
            }
            for (a, b) in y_slice.data.iter().zip(&y_store.data) {
                prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "quant-off TieredStore diverged from the fp32 slice path"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_int8_dispatch_divergence_within_gate_weighted_bound() {
    let mut diverged = 0u64;
    prop::check(
        "per-token |int8 - fp32| <= sum_k |gate_k| * bound_k(x)",
        prop::Config { cases: 30, seed: 0x1A2B, max_size: 10 },
        |rng: &mut Rng, size| {
            let d = 4 + rng.below(12);
            let m = 4 + rng.below(24);
            let n_r = 2 + rng.below(5);
            let tokens = 1 + rng.below(size.max(1) * 2);
            let es = experts(rng, n_r, d, m);
            let es_q: Vec<QuantizedFfn> = es.iter().map(QuantizedFfn::quantize).collect();
            let decisions = random_decisions(rng, tokens, n_r);
            // three input scales: the bound must hold away from the
            // unit-variance regime too
            let scale = [0.5f32, 1.0, 2.0][rng.below(3)];
            let xn = Tensor::randn(rng, &[tokens, d], scale);

            let y_fp = dispatch(&xn, &decisions, es.as_slice(), n_r, m);
            let store = TieredStore::new(&es, true, n_r);
            let y_q = dispatch(&xn, &decisions, &store, n_r, m);

            for (tk, dec) in decisions.iter().enumerate() {
                let row = &xn.data[tk * d..(tk + 1) * d];
                let bound_t: f32 = dec
                    .experts
                    .iter()
                    .zip(&dec.gates)
                    .map(|(&e, &g)| g.abs() * es_q[e].divergence_bound(row))
                    .sum();
                let worst_t = y_q.data[tk * d..(tk + 1) * d]
                    .iter()
                    .zip(&y_fp.data[tk * d..(tk + 1) * d])
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                prop_assert!(
                    worst_t <= bound_t * 1.01 + 1e-4,
                    "token {tk}: divergence {worst_t} exceeds bound {bound_t} (d={d} m={m})"
                );
                if worst_t > 0.0 {
                    diverged += 1;
                }
            }
            Ok(())
        },
    );
    assert!(diverged > 0, "int8 never diverged from fp32 — the property is vacuous");
}

/// Independent shadow of the residency policy: f32 EMA recomputed from
/// scratch, warm set = top-cap by (EMA desc, index asc), transitions
/// counted against the pre-update residency.
struct Shadow {
    ema: Vec<f32>,
    warm: Vec<bool>,
    cap: usize,
}

impl Shadow {
    fn new(n: usize, cap: usize) -> Shadow {
        Shadow { ema: vec![0.0; n], warm: (0..n).map(|e| e < cap).collect(), cap }
    }

    fn step(&mut self, counts: &[usize]) -> (u64, u64, u64, u64) {
        let (mut hits, mut misses, mut pf, mut dm) = (0, 0, 0, 0);
        for (e, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if self.warm[e] {
                hits += 1;
            } else {
                misses += 1;
            }
        }
        let total: usize = counts.iter().sum();
        for (e, &c) in counts.iter().enumerate() {
            let frac = if total == 0 { 0.0 } else { c as f32 / total as f32 };
            self.ema[e] = RESIDENCY_EMA_DECAY * self.ema[e] + (1.0 - RESIDENCY_EMA_DECAY) * frac;
        }
        let mut order: Vec<usize> = (0..counts.len()).collect();
        order.sort_by(|&a, &b| {
            self.ema[b]
                .partial_cmp(&self.ema[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        for (rank, &e) in order.iter().enumerate() {
            let want = rank < self.cap;
            match (self.warm[e], want) {
                (false, true) => pf += 1,
                (true, false) => dm += 1,
                _ => {}
            }
            self.warm[e] = want;
        }
        (hits, misses, pf, dm)
    }
}

#[test]
fn prop_residency_trace_matches_shadow_model_exactly() {
    prop::check(
        "note_step == independent shadow on drifting traces, no lost experts",
        prop::Config { cases: 25, seed: 0x7E5D, max_size: 8 },
        |rng: &mut Rng, size| {
            let n_r = 3 + rng.below(size.max(1) + 2);
            let cap = 1 + rng.below(n_r);
            let d = 4;
            let m = 8;
            let es = experts(rng, n_r, d, m);
            let mut store = TieredStore::new(&es, true, cap);
            let mut shadow = Shadow::new(n_r, store.resident_cap());
            prop_assert!(store.resident_cap() == cap, "cap {cap} clamped unexpectedly");

            // drifting hotspot: the preferred expert subset rotates
            let mut hot: Vec<usize> = (0..n_r).collect();
            for step in 0..160 {
                if step % 30 == 0 {
                    // deterministic rotation + occasional shuffle
                    hot.rotate_left(1 + rng.below(n_r.max(2) - 1));
                }
                let mut counts = vec![0usize; n_r];
                for _ in 0..12 {
                    let e = if rng.f32() < 0.8 { hot[rng.below(2.min(n_r))] } else { rng.below(n_r) };
                    counts[e] += 1;
                }
                let got = store.note_step(&counts);
                let (hits, misses, pf, dm) = shadow.step(&counts);
                prop_assert!(
                    (got.hits, got.misses, got.prefetches, got.demotions)
                        == (hits, misses, pf, dm),
                    "step {step}: note_step {got:?} != shadow ({hits},{misses},{pf},{dm})"
                );
                // hit/miss conservation: every routed expert is one or
                // the other, never both, never neither
                let routed = counts.iter().filter(|&&c| c > 0).count() as u64;
                prop_assert!(got.hits + got.misses == routed, "hit/miss leak at step {step}");
                // exactly cap experts warm; every expert still viewable
                let warm = (0..n_r)
                    .filter(|&e| store.residency(e) == ExpertResidency::Int8Resident)
                    .count();
                prop_assert!(warm == store.resident_cap(), "warm set {warm} != cap");
                for e in 0..n_r {
                    prop_assert!(
                        matches!(store.view(e), ExpertView::Int8(_)),
                        "expert {e} lost its view"
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn routing_counts_drive_the_tier_like_the_engine_does() {
    // the engine feeds note_step from GroupedRouting::count — wire the
    // same path here and pin the drift story end to end: cold experts
    // miss, then prefetch exactly once each while the drifted-from
    // experts demote exactly once each
    let mut rng = Rng::new(0xD15C);
    let (d, m, n_r, cap) = (8, 16, 4, 2);
    let es = experts(&mut rng, n_r, d, m);
    let mut store = TieredStore::new(&es, true, cap);
    let xn = Tensor::randn(&mut rng, &[16, d], 1.0);
    let mut routing = GroupedRouting::new(n_r);
    let disp = GroupedDispatcher::new(d, m);
    let mut arena = DispatchArena::new();
    let mut out = Tensor::zeros(&[16, d]);

    let route_to = |rng: &mut Rng, pair: [usize; 2]| -> Vec<GateDecision> {
        (0..16)
            .map(|_| GateDecision {
                experts: vec![pair[rng.below(2)]],
                gates: vec![1.0],
                scores: vec![0.0; n_r],
            })
            .collect()
    };

    let mut run_phase = |store: &mut TieredStore, rng: &mut Rng, pair, steps| {
        let mut agg = (0u64, 0u64, 0u64, 0u64);
        for _ in 0..steps {
            let decisions = route_to(rng, pair);
            routing.rebuild(n_r, &decisions);
            let counts: Vec<usize> = (0..n_r).map(|e| routing.count(e)).collect();
            let delta = store.note_step(&counts);
            agg.0 += delta.hits;
            agg.1 += delta.misses;
            agg.2 += delta.prefetches;
            agg.3 += delta.demotions;
            // the dispatch itself must run regardless of residency
            out.data.fill(0.0);
            disp.forward(&xn, &routing, &*store, &mut arena, &mut out);
            assert!(out.data.iter().all(|v| v.is_finite()));
        }
        agg
    };

    let (_, misses_a, pf_a, dm_a) = run_phase(&mut store, &mut rng, [0, 1], 8);
    assert_eq!((misses_a, pf_a, dm_a), (0, 0, 0), "warm phase was not clean");
    let (_, misses_b, pf_b, dm_b) = run_phase(&mut store, &mut rng, [2, 3], 20);
    assert!(misses_b > 0, "cold experts never missed before promotion");
    assert_eq!((pf_b, dm_b), (2, 2), "drift must promote and demote exactly once each");
    assert_eq!(store.residency(2), ExpertResidency::Int8Resident);
    assert_eq!(store.residency(0), ExpertResidency::Int8Host);
    // resident_bytes tracks the warm set only
    let warm_bytes = store.resident_bytes();
    let all_warm = TieredStore::new(&es, true, n_r).resident_bytes();
    assert!(warm_bytes < all_warm, "cold experts still counted as resident bytes");
}
