//! Fault-containment suite (host-only): drives [`ContinuousSession`]
//! through a [`FaultInjectingForward`] and pins the ISSUE-6 contract —
//! **any single injected forward failure degrades one request at a
//! time, never the process**.
//!
//! * A transient batch-level fault (one failed prefill or decode call)
//!   is invisible: the session isolates the batch, retries each
//!   request alone, and every token stream still matches the
//!   unfaulted reference.
//! * A deterministic per-request fault (poison token) retires exactly
//!   the poisoned request with a typed [`RequestFailure`]; everyone
//!   else completes bit-exactly and the session keeps serving.
//! * Under random seeded fault rates, completed + failed ids always
//!   partition the submitted ids, completed streams are token-exact,
//!   and no KV page or slot context outlives the trace.

use cmoe::prop_assert;
use cmoe::serving::{
    stub_reference, BatcherConfig, Clock, ContinuousSession, FaultInjectingForward, GenParams,
    Request, StubForward,
};
use cmoe::util::prop;
use cmoe::util::Rng;
use std::collections::VecDeque;
use std::time::Duration;

const VOCAB: usize = 17;
const KV_CAP: usize = 48;

fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
    let prompt = (0..prompt_len.max(1)).map(|j| (id as usize * 31 + j * 7) % VOCAB).collect();
    Request::new(
        id,
        prompt,
        GenParams { max_new_tokens: max_new, temperature: 0.0, seed: id, stop_token: None },
    )
}

fn session(
    buckets: Vec<usize>,
    seed: u64,
) -> ContinuousSession<FaultInjectingForward<StubForward>> {
    let pool = *buckets.iter().max().unwrap();
    ContinuousSession::with_clock(
        BatcherConfig { buckets, max_wait: Duration::ZERO, ..Default::default() },
        FaultInjectingForward::new(StubForward::new(pool, VOCAB, KV_CAP), seed),
        Clock::manual(),
    )
    .unwrap()
}

#[test]
fn single_prefill_fault_is_invisible_after_isolation() {
    let mut sess = session(vec![4], 1);
    let reqs: Vec<Request> = (0..4).map(|i| req(i, 4, 5)).collect();
    for r in &reqs {
        sess.enqueue(r.clone());
    }
    sess.forward_mut().fail_next_prefill = 1; // the whole first batch fails once
    let results = sess.drain().unwrap();
    assert!(sess.take_failures().is_empty(), "isolated retries must succeed");
    assert_eq!(results.len(), 4);
    for r in &results {
        let want = stub_reference(&reqs[r.id as usize], VOCAB, KV_CAP);
        assert_eq!(r.tokens, want, "request {} diverged across fault recovery", r.id);
    }
    assert_eq!(sess.forward().injected, 1);
    assert!(sess.metrics().faults_contained >= 1);
    assert_eq!(sess.metrics().failed, 0);
    assert_eq!(sess.forward().inner().live_contexts(), 0);
}

#[test]
fn single_decode_fault_is_invisible_after_isolation() {
    let mut sess = session(vec![4], 1);
    let reqs: Vec<Request> = (0..4).map(|i| req(i, 3, 6)).collect();
    for r in &reqs {
        sess.enqueue(r.clone());
    }
    sess.forward_mut().fail_next_decode = 1; // the first batched decode step fails
    let results = sess.drain().unwrap();
    assert!(sess.take_failures().is_empty());
    assert_eq!(results.len(), 4);
    for r in &results {
        let want = stub_reference(&reqs[r.id as usize], VOCAB, KV_CAP);
        assert_eq!(r.tokens, want, "request {} diverged across decode recovery", r.id);
    }
    assert!(sess.metrics().faults_contained >= 1);
    assert_eq!(sess.metrics().failed, 0);
    assert_eq!(sess.forward().inner().kv().pages().pages_in_use(), 0);
}

#[test]
fn poison_token_retires_exactly_one_request_with_typed_error() {
    const POISON: usize = 999; // outside every generated prompt
    let mut sess = session(vec![4], 1);
    let mut reqs: Vec<Request> = (0..4).map(|i| req(i, 4, 5)).collect();
    reqs[2].prompt[1] = POISON;
    for r in &reqs {
        sess.enqueue(r.clone());
    }
    sess.forward_mut().poison_token = Some(POISON);
    let results = sess.drain().unwrap();
    let failures = sess.take_failures();
    assert_eq!(
        failures.iter().map(|f| f.id).collect::<Vec<_>>(),
        vec![2],
        "exactly the poisoned request must fail"
    );
    assert!(
        failures[0].error.contains("poison token"),
        "failure must carry the typed cause, got: {}",
        failures[0].error
    );
    let mut ok_ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ok_ids.sort_unstable();
    assert_eq!(ok_ids, vec![0, 1, 3], "everyone else keeps serving");
    for r in &results {
        let want = stub_reference(&reqs[r.id as usize], VOCAB, KV_CAP);
        assert_eq!(r.tokens, want, "survivor {} diverged", r.id);
    }
    assert_eq!(sess.metrics().failed, 1);
    assert_eq!(sess.forward().inner().live_contexts(), 0, "failed slot leaked its context");
    assert_eq!(sess.forward().inner().kv().pages().pages_in_use(), 0, "failed slot leaked KV");
}

#[test]
fn session_survives_a_fault_mid_stream_and_keeps_admitting() {
    // fault fires while requests are in flight; later arrivals are
    // admitted and served normally afterwards
    let mut sess = session(vec![2], 1);
    sess.enqueue(req(0, 3, 8));
    sess.enqueue(req(1, 3, 8));
    sess.step().unwrap();
    sess.forward_mut().fail_next_decode = 1;
    sess.step().unwrap(); // the contained fault
    sess.enqueue(req(2, 3, 2)); // arrives after the fault
    let results = sess.drain().unwrap();
    assert!(sess.take_failures().is_empty());
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2]);
    for r in &results {
        let want = stub_reference(&req(r.id, 3, if r.id == 2 { 2 } else { 8 }), VOCAB, KV_CAP);
        assert_eq!(r.tokens, want, "request {} diverged", r.id);
    }
    assert!(sess.metrics().faults_contained >= 1);
}

#[test]
fn prop_random_faults_partition_requests_and_leak_nothing() {
    let mut total_failed = 0u64;
    let mut total_completed = 0u64;
    let mut total_contained = 0u64;
    prop::check(
        "random fault schedules degrade per-request, never the process",
        prop::Config { cases: 60, seed: 0xFA17, max_size: 18 },
        |rng: &mut Rng, size| {
            let buckets = vec![1 + rng.below(3)];
            let n_req = 1 + rng.below(size.max(1));
            let mut sess = session(buckets, rng.next_u64());
            {
                let f = sess.forward_mut();
                f.p_map = if rng.f32() < 0.5 { 0.1 } else { 0.0 };
                f.p_prefill = if rng.f32() < 0.5 { 0.15 } else { 0.0 };
                f.p_decode = if rng.f32() < 0.5 { 0.05 } else { 0.0 };
            }
            let reqs: Vec<Request> = (0..n_req)
                .map(|i| req(i as u64, 1 + rng.below(6), 1 + rng.below(8)))
                .collect();
            let mut pending: VecDeque<Request> = reqs.iter().cloned().collect();
            let mut results = Vec::new();
            let mut guard = 0;
            while !(pending.is_empty() && sess.is_idle()) {
                for _ in 0..rng.below(3) {
                    if let Some(r) = pending.pop_front() {
                        sess.enqueue(r);
                    }
                }
                // the containment contract itself: step() never errors,
                // whatever the injector does
                results.extend(
                    sess.step().map_err(|e| format!("fault escaped containment: {e:#}"))?,
                );
                guard += 1;
                prop_assert!(guard < 100_000, "faulted trace failed to converge");
            }
            let failures = sess.take_failures();
            let mut ids: Vec<u64> = results
                .iter()
                .map(|r| r.id)
                .chain(failures.iter().map(|f| f.id))
                .collect();
            ids.sort_unstable();
            let want_ids: Vec<u64> = (0..n_req as u64).collect();
            prop_assert!(
                ids == want_ids,
                "completed+failed must partition submitted ids: {ids:?} != {want_ids:?}"
            );
            for r in &results {
                let want = stub_reference(&reqs[r.id as usize], VOCAB, KV_CAP);
                prop_assert!(
                    r.tokens == want,
                    "completed request {} diverged under faults: {:?} != {want:?}",
                    r.id,
                    r.tokens
                );
            }
            for f in &failures {
                prop_assert!(!f.error.is_empty(), "failure without a typed cause");
            }
            prop_assert!(
                sess.forward().inner().live_contexts() == 0,
                "leaked {} contexts",
                sess.forward().inner().live_contexts()
            );
            prop_assert!(
                sess.forward().inner().kv().pages().pages_in_use() == 0,
                "leaked {} pages",
                sess.forward().inner().kv().pages().pages_in_use()
            );
            let m = sess.metrics();
            prop_assert!(
                m.failed == failures.len() as u64,
                "failed gauge {} != {} typed failures",
                m.failed,
                failures.len()
            );
            total_failed += m.failed;
            total_completed += results.len() as u64;
            total_contained += m.faults_contained;
            Ok(())
        },
    );
    // the property is only meaningful if all three regimes occurred
    assert!(total_contained > 0, "no fault was ever injected — property is vacuous");
    assert!(total_failed > 0, "no request ever failed — per-request path unexercised");
    assert!(total_completed > 0, "nothing ever completed under faults");
}
