//! Integration: the full user pipeline — profile → convert → fine-tune
//! → save → load → serve — plus robustness of the persistence layer.

use cmoe::converter::{convert_model, ConvertOptions};
use cmoe::eval::forward::DenseForward;
use cmoe::model::{model_config, LayerFfn, ModelWeights};
use cmoe::profiling::ActivationProfile;
use cmoe::util::Rng;

fn converted_tiny(rng: &mut Rng) -> (ModelWeights, ModelWeights) {
    let cfg = model_config("tiny").unwrap();
    let dense = ModelWeights::random(&cfg, rng);
    let calib: Vec<usize> = (0..96).map(|_| rng.below(cfg.vocab)).collect();
    let profiles: Vec<ActivationProfile> = DenseForward::new(&dense)
        .capture_hidden(&calib)
        .iter()
        .map(|h| ActivationProfile::from_hidden(h, 24))
        .collect();
    let moe = convert_model(&dense, &profiles, &"S2A2E8".parse().unwrap(), &ConvertOptions::default())
        .unwrap()
        .model;
    (dense, moe)
}

#[test]
fn convert_save_load_preserves_forward_exactly() {
    let mut rng = Rng::new(601);
    let (_, moe) = converted_tiny(&mut rng);
    let path = std::env::temp_dir().join("cmoe_rt_moe.cmw");
    moe.save(&path).unwrap();
    let back = ModelWeights::load(&path).unwrap();

    // identical forward on identical inputs (bit-exact weights)
    let tokens: Vec<usize> = (0..10).map(|_| rng.below(256)).collect();
    let a = DenseForward::new(&moe).logits(&tokens);
    let b = DenseForward::new(&back).logits(&tokens);
    assert_eq!(a.data, b.data, "save/load changed the model");

    // MoE bookkeeping survives
    for (la, lb) in moe.layers.iter().zip(&back.layers) {
        let (LayerFfn::Moe(ma), LayerFfn::Moe(mb)) = (&la.ffn, &lb.ffn) else {
            panic!("layer kind lost");
        };
        assert_eq!(ma.spec, mb.spec);
        assert_eq!(ma.shared_neurons, mb.shared_neurons);
        assert_eq!(ma.expert_neurons, mb.expert_neurons);
        assert_eq!(ma.representatives, mb.representatives);
        assert_eq!(ma.gate_bias, mb.gate_bias);
    }
}

#[test]
fn finetuned_gates_survive_roundtrip() {
    let mut rng = Rng::new(602);
    let (dense, mut moe) = converted_tiny(&mut rng);
    // fine-tune gates so u != 0, bias != 0
    let calib: Vec<usize> = (0..128).map(|_| rng.below(256)).collect();
    let inputs = DenseForward::new(&dense).capture_ffn_inputs(&calib);
    for (l, layer) in moe.layers.iter_mut().enumerate() {
        if let LayerFfn::Moe(m) = &mut layer.ffn {
            cmoe::moe::finetune_gates(m, &inputs[l], &cmoe::moe::FinetuneConfig::default());
        }
    }
    let path = std::env::temp_dir().join("cmoe_rt_ft.cmw");
    moe.save(&path).unwrap();
    let back = ModelWeights::load(&path).unwrap();
    for (la, lb) in moe.layers.iter().zip(&back.layers) {
        let (LayerFfn::Moe(ma), LayerFfn::Moe(mb)) = (&la.ffn, &lb.ffn) else { unreachable!() };
        assert_eq!(ma.gate_scale, mb.gate_scale);
        assert!(ma.gate_scale.iter().any(|&u| u != 0.0), "fine-tune was a no-op");
    }
}

#[test]
fn truncated_cmw_rejected_gracefully() {
    let mut rng = Rng::new(603);
    let (_, moe) = converted_tiny(&mut rng);
    let path = std::env::temp_dir().join("cmoe_rt_trunc.cmw");
    moe.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // chop the payload at several points — must error, never panic
    for frac in [0.1, 0.5, 0.95] {
        let cut = (bytes.len() as f64 * frac) as usize;
        let tpath = std::env::temp_dir().join(format!("cmoe_rt_trunc_{cut}.cmw"));
        std::fs::write(&tpath, &bytes[..cut]).unwrap();
        assert!(ModelWeights::load(&tpath).is_err(), "truncation at {frac} accepted");
    }
}

#[test]
fn corrupted_header_rejected_gracefully() {
    let mut rng = Rng::new(604);
    let (dense, _) = converted_tiny(&mut rng);
    let path = std::env::temp_dir().join("cmoe_rt_corrupt.cmw");
    dense.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // scribble over the JSON header region
    for b in bytes[16..48].iter_mut() {
        *b = b'#';
    }
    std::fs::write(&path, &bytes).unwrap();
    assert!(ModelWeights::load(&path).is_err());
}

#[test]
fn quantized_converted_model_roundtrips_and_serves_reference() {
    // §6 composition through persistence: quantize(convert(m)) →
    // save → load → forward is finite and close to unquantized
    let mut rng = Rng::new(605);
    let (_, moe) = converted_tiny(&mut rng);
    let q = cmoe::quant::quantize_model(&moe);
    let path = std::env::temp_dir().join("cmoe_rt_quant.cmw");
    q.save(&path).unwrap();
    let back = ModelWeights::load(&path).unwrap();
    let tokens: Vec<usize> = (0..8).map(|_| rng.below(256)).collect();
    let a = DenseForward::new(&moe).logits(&tokens);
    let b = DenseForward::new(&back).logits(&tokens);
    let mut diff = a.clone();
    for (x, y) in diff.data.iter_mut().zip(&b.data) {
        *x -= y;
    }
    assert!(b.data.iter().all(|v| v.is_finite()));
    assert!(
        (diff.norm() / a.norm()) < 0.2,
        "int8 drift too large: {}",
        diff.norm() / a.norm()
    );
}

#[test]
fn server_concurrent_submitters() {
    // EngineServer under concurrent producers: every ticket resolves,
    // ids map to the right results (needs artifacts; self-skips)
    let Some(dir) = cmoe::test_artifact_dir() else { return };
    let mut rng = Rng::new(606);
    let cfg = model_config("tiny").unwrap();
    let dense = ModelWeights::random(&cfg, &mut rng);
    let mut ecfg = cmoe::serving::EngineConfig::dense("tiny", 128);
    ecfg.batcher.buckets = vec![1];
    ecfg.batcher.max_wait = std::time::Duration::ZERO;
    let server =
        std::sync::Arc::new(cmoe::serving::EngineServer::start(dir, dense, ecfg).unwrap());
    let mut handles = Vec::new();
    for tid in 0..4u64 {
        let s = server.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..3u64 {
                let id = tid * 100 + i;
                let prompt = vec![(id % 250) as usize; 6];
                let ticket = s.submit(cmoe::serving::Request::new(
                    id,
                    prompt,
                    cmoe::serving::GenParams { max_new_tokens: 2, ..Default::default() },
                ));
                let r = ticket.wait().unwrap();
                assert_eq!(r.id, id);
                assert_eq!(r.tokens.len(), 2);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
