//! Randomized property suite for the paged KV pool
//! (`runtime::PagePool` + `runtime::KvSlotPool`): alloc / map_shared /
//! write (COW) / release traces checked against a shadow model after
//! every operation.
//!
//! Invariants pinned (the ISSUE-5 acceptance list):
//! * every page's refcount equals its live mappings (slot page tables
//!   + cache-like holds) — no page is leaked or double-freed, and a
//!   drained trace ends with zero pages in use;
//! * the high-water page gauge is monotone and equals the max
//!   pages-in-use ever observed;
//! * a recycled page never leaks stale KV: positions a slot never
//!   wrote read zero, even after heavy recycling (extends the
//!   stale-data guarantee documented in `runtime/kv_pool.rs`);
//! * copy-on-write isolates divergent writes: writing into a shared
//!   page changes only the writer's view, every other holder keeps
//!   the original bytes;
//! * `gather_full` agrees with per-token reads and zero-fills beyond
//!   each slot's extent;
//! * park / unpark / drop (the preemption lifecycle, ISSUE-6): a
//!   parked table keeps its page references and bytes verbatim,
//!   unparking into any empty slot restores the identical page table,
//!   and dropping a parked table releases exactly its references.

use cmoe::prop_assert;
use cmoe::runtime::{KvSlotPool, ParkedSlot};
use cmoe::util::prop;
use cmoe::util::Rng;
use std::collections::{HashMap, HashSet};

const LAYERS: usize = 2;
const HEADS: usize = 1;
const HD: usize = 1;
/// Token column elements: LAYERS * 2 * HEADS * HD.
const COL: usize = 4;
const PAGE_LEN: usize = 3;
const KV_LEN: usize = 60;
const POOL: usize = 5;

type Col = [f32; COL];

/// Shadow model: expected token columns per live slot, plus the
/// expected content of every cache-like page hold.
#[derive(Default)]
struct Shadow {
    slots: Vec<Option<Vec<Col>>>,
    /// (held page ids, expected columns covering them fully).
    held: Vec<(Vec<usize>, Vec<Col>)>,
    /// Parked tables: (handle, page-id snapshot, expected columns).
    parked: Vec<(ParkedSlot, Vec<usize>, Vec<Col>)>,
}

fn write_shadow(cols: &mut Vec<Col>, pos: usize, col: Col) {
    if cols.len() <= pos {
        cols.resize(pos + 1, [0.0; COL]);
    }
    cols[pos] = col;
}

/// Check every invariant the trace is about.
fn check(kv: &KvSlotPool, sh: &Shadow, hw_seen: &mut usize) -> Result<(), String> {
    // per-slot content: extent and every token column
    let mut col = [0.0f32; COL];
    for (s, exp) in sh.slots.iter().enumerate() {
        match exp {
            None => {
                prop_assert!(kv.extent(s) == 0, "released slot {s} kept extent {}", kv.extent(s));
                prop_assert!(kv.slot_pages(s).is_empty(), "released slot {s} kept pages");
            }
            Some(cols) => {
                prop_assert!(
                    kv.extent(s) == cols.len(),
                    "slot {s} extent {} != shadow {}",
                    kv.extent(s),
                    cols.len()
                );
                for (t, want) in cols.iter().enumerate() {
                    kv.read_token(s, t, &mut col);
                    prop_assert!(
                        col == *want,
                        "slot {s} pos {t}: {col:?} != {want:?} (stale or aliased page)"
                    );
                }
            }
        }
    }
    // held (cache-like) pages keep their bytes regardless of slot writes
    for (pages, cols) in &sh.held {
        for (pi, &p) in pages.iter().enumerate() {
            let page = kv.pages().page(p);
            for tp in 0..PAGE_LEN {
                let want = cols[pi * PAGE_LEN + tp];
                for (ph, &w) in want.iter().enumerate() {
                    let got = page[(ph * PAGE_LEN + tp) * HD];
                    prop_assert!(
                        got == w,
                        "held page {p} tok {tp} plane {ph}: {got} != {w} (COW failed to isolate)"
                    );
                }
            }
        }
    }
    // refcounts == live mappings; pages_in_use == distinct references
    let mut refs: HashMap<usize, u32> = HashMap::new();
    for s in 0..POOL {
        for &p in kv.slot_pages(s) {
            *refs.entry(p).or_insert(0) += 1;
        }
    }
    for (pages, _) in &sh.held {
        for &p in pages {
            *refs.entry(p).or_insert(0) += 1;
        }
    }
    for (h, pages, cols) in &sh.parked {
        prop_assert!(
            h.page_count() == pages.len(),
            "parked handle reports {} pages, snapshot has {}",
            h.page_count(),
            pages.len()
        );
        prop_assert!(
            h.tokens() == cols.len(),
            "parked handle reports {} tokens, shadow has {}",
            h.tokens(),
            cols.len()
        );
        for &p in pages {
            *refs.entry(p).or_insert(0) += 1;
        }
    }
    for (&p, &n) in &refs {
        prop_assert!(
            kv.pages().refcount(p) == n,
            "page {p} refcount {} != {n} live mappings",
            kv.pages().refcount(p)
        );
    }
    let distinct: HashSet<usize> = refs.keys().copied().collect();
    prop_assert!(
        kv.pages().pages_in_use() == distinct.len(),
        "pages_in_use {} != {} referenced",
        kv.pages().pages_in_use(),
        distinct.len()
    );
    // high-water: monotone and exactly the max in-use observed
    prop_assert!(
        kv.pages().high_water_pages >= *hw_seen,
        "high water went down: {} < {hw_seen}",
        kv.pages().high_water_pages
    );
    *hw_seen = (*hw_seen).max(kv.pages().pages_in_use());
    prop_assert!(
        kv.pages().high_water_pages == *hw_seen,
        "high water {} != max in-use {hw_seen}",
        kv.pages().high_water_pages
    );
    // gather agrees with token reads and zero-fills beyond the extent
    if let Some((s, cols)) = sh.slots.iter().enumerate().find_map(|(s, c)| {
        c.as_ref().map(|c| (s, c))
    }) {
        let mut buf = Vec::new();
        kv.gather_full(&[s], 1, &mut buf);
        for lc in 0..LAYERS * 2 {
            for t in 0..KV_LEN {
                let got = buf[lc * KV_LEN + t];
                // pages are zero beyond written positions, so gather of
                // a mapped page's tail is 0 exactly like unmapped space
                let want = if t < cols.len() { cols[t][lc] } else { 0.0 };
                prop_assert!(
                    got == want,
                    "gather slot {s} lc {lc} tok {t}: {got} != {want}"
                );
            }
        }
    }
    Ok(())
}

#[test]
fn prop_page_traces_never_leak_alias_or_stale() {
    // ≥ 200 randomized traces (the acceptance floor), ~size ops each
    prop::check(
        "paged KV traces: refcounts, COW isolation, zero-fill, no leaks",
        prop::Config { cases: 220, seed: 0x9A6E5, max_size: 36 },
        |rng: &mut Rng, size| {
            let mut kv = KvSlotPool::new(POOL, LAYERS, HEADS, KV_LEN, HD, PAGE_LEN, None);
            let mut sh = Shadow {
                slots: (0..POOL).map(|_| None).collect(),
                held: Vec::new(),
                parked: Vec::new(),
            };
            let mut hw_seen = 0usize;
            let mut stamp = 0f32;
            let fresh_col = |stamp: &mut f32| -> Col {
                *stamp += 1.0;
                [*stamp, -*stamp, *stamp + 1000.0, -*stamp - 1000.0]
            };
            for _ in 0..3 * size {
                match rng.below(8) {
                    // admit: map an optional held prefix, then write a suffix
                    0 | 1 => {
                        let Some(slot) = (0..POOL).find(|&s| sh.slots[s].is_none()) else {
                            continue;
                        };
                        let mut cols: Vec<Col> = Vec::new();
                        let mut start = 0usize;
                        if !sh.held.is_empty() && rng.f32() < 0.6 {
                            let (pages, held_cols) = &sh.held[rng.below(sh.held.len())];
                            let k = 1 + rng.below(pages.len());
                            kv.map_shared(slot, &pages[..k], k * PAGE_LEN);
                            cols.extend_from_slice(&held_cols[..k * PAGE_LEN]);
                            start = k * PAGE_LEN;
                        }
                        let len = (start + rng.below(12)).min(KV_LEN);
                        for t in start..len {
                            let c = fresh_col(&mut stamp);
                            kv.write_token(slot, t, &c);
                            write_shadow(&mut cols, t, c);
                        }
                        sh.slots[slot] = Some(cols);
                    }
                    // write more (decode-like growth, occasionally sparse
                    // — the gap positions must read zero later)
                    2 => {
                        let live: Vec<usize> =
                            (0..POOL).filter(|&s| sh.slots[s].is_some()).collect();
                        if live.is_empty() {
                            continue;
                        }
                        let slot = live[rng.below(live.len())];
                        let cols = sh.slots[slot].as_mut().unwrap();
                        let pos = (cols.len() + rng.below(4)).min(KV_LEN - 1);
                        let c = fresh_col(&mut stamp);
                        kv.write_token(slot, pos, &c);
                        write_shadow(cols, pos, c);
                    }
                    // divergent write into the mapped prefix: COW must
                    // isolate it from every other holder
                    3 => {
                        let live: Vec<usize> =
                            (0..POOL).filter(|&s| sh.slots[s].is_some()).collect();
                        if live.is_empty() {
                            continue;
                        }
                        let slot = live[rng.below(live.len())];
                        let cols = sh.slots[slot].as_mut().unwrap();
                        if cols.is_empty() {
                            continue;
                        }
                        let pos = rng.below(cols.len());
                        let c = fresh_col(&mut stamp);
                        kv.write_token(slot, pos, &c);
                        write_shadow(cols, pos, c);
                    }
                    // hold: a cache-like reference to a slot's leading
                    // fully-written pages
                    4 => {
                        let live: Vec<usize> =
                            (0..POOL).filter(|&s| sh.slots[s].is_some()).collect();
                        if live.is_empty() {
                            continue;
                        }
                        let slot = live[rng.below(live.len())];
                        let cols = sh.slots[slot].as_ref().unwrap();
                        let full = cols.len() / PAGE_LEN;
                        if full == 0 {
                            continue;
                        }
                        let k = 1 + rng.below(full);
                        let pages: Vec<usize> = kv.slot_pages(slot)[..k].to_vec();
                        for &p in &pages {
                            kv.pages_mut().retain(p);
                        }
                        sh.held.push((pages, cols[..k * PAGE_LEN].to_vec()));
                    }
                    // park: detach a live slot's table — refcounts and
                    // bytes must be untouched while it sits parked
                    5 => {
                        let live: Vec<usize> =
                            (0..POOL).filter(|&s| sh.slots[s].is_some()).collect();
                        if live.is_empty() {
                            continue;
                        }
                        let slot = live[rng.below(live.len())];
                        let pages = kv.slot_pages(slot).to_vec();
                        let cols = sh.slots[slot].take().unwrap();
                        let h = kv.park(slot);
                        sh.parked.push((h, pages, cols));
                    }
                    // unpark into any empty slot: the identical page
                    // table (and so the identical bytes) must come back
                    6 => {
                        if sh.parked.is_empty() {
                            continue;
                        }
                        let Some(slot) = (0..POOL).find(|&s| sh.slots[s].is_none()) else {
                            continue;
                        };
                        let (h, pages, cols) =
                            sh.parked.swap_remove(rng.below(sh.parked.len()));
                        kv.unpark(slot, h);
                        prop_assert!(
                            kv.slot_pages(slot) == &pages[..],
                            "unpark changed the page table: {:?} != {pages:?}",
                            kv.slot_pages(slot)
                        );
                        sh.slots[slot] = Some(cols);
                    }
                    // release a slot, drop a hold, or drop a parked table
                    _ => match rng.below(3) {
                        0 => {
                            let live: Vec<usize> =
                                (0..POOL).filter(|&s| sh.slots[s].is_some()).collect();
                            if live.is_empty() {
                                continue;
                            }
                            let slot = live[rng.below(live.len())];
                            kv.release(slot);
                            sh.slots[slot] = None;
                        }
                        1 if !sh.held.is_empty() => {
                            let (pages, _) = sh.held.swap_remove(rng.below(sh.held.len()));
                            for &p in &pages {
                                kv.pages_mut().release(p);
                            }
                        }
                        2 if !sh.parked.is_empty() => {
                            let (h, _, _) =
                                sh.parked.swap_remove(rng.below(sh.parked.len()));
                            kv.drop_parked(h);
                        }
                        _ => continue,
                    },
                }
                check(&kv, &sh, &mut hw_seen)?;
            }
            // drain everything: no page may survive its last reference
            for s in 0..POOL {
                if sh.slots[s].is_some() {
                    kv.release(s);
                    sh.slots[s] = None;
                }
            }
            for (pages, _) in sh.held.drain(..) {
                for p in pages {
                    kv.pages_mut().release(p);
                }
            }
            for (h, _, _) in sh.parked.drain(..) {
                kv.drop_parked(h);
            }
            prop_assert!(
                kv.pages().pages_in_use() == 0,
                "trace leaked {} pages",
                kv.pages().pages_in_use()
            );
            check(&kv, &sh, &mut hw_seen)
        },
    );
}

#[test]
fn recycled_pages_read_zero_after_dirty_history() {
    // pointed stale-data check on top of the randomized one: fill a
    // slot with non-zero KV, release it, then write sparsely into a
    // fresh slot — every recycled page position not written must be 0
    let mut kv = KvSlotPool::new(2, LAYERS, HEADS, KV_LEN, HD, PAGE_LEN, None);
    for t in 0..12 {
        kv.write_token(0, t, &[9.0; COL]);
    }
    kv.release(0);
    assert_eq!(kv.pages().pages_in_use(), 0);
    kv.write_token(1, 10, &[5.0; COL]); // recycles the dirty pages
    let mut col = [1.0f32; COL];
    for t in 0..10 {
        kv.read_token(1, t, &mut col);
        assert_eq!(col, [0.0; COL], "stale KV leaked into recycled page at pos {t}");
    }
    kv.read_token(1, 10, &mut col);
    assert_eq!(col, [5.0; COL]);
}

#[test]
fn bounded_pool_exhaustion_is_loud_not_corrupt() {
    // 2 slots × 2 pages budget: a third slot's write must panic (the
    // engine reserves/evicts first; silent reuse would alias KV)
    let mut kv = KvSlotPool::new(3, LAYERS, HEADS, 2 * PAGE_LEN, HD, PAGE_LEN, Some(4));
    for s in 0..2 {
        for t in 0..2 * PAGE_LEN {
            kv.write_token(s, t, &[s as f32; COL]);
        }
    }
    assert_eq!(kv.pages_available(), Some(0));
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        kv.write_token(2, 0, &[7.0; COL]);
    }));
    assert!(err.is_err(), "exhausted pool must refuse to allocate");
}
