//! Integration: the serving engine end-to-end in all three exec modes,
//! through both scheduling paths — continuous in-flight batching (the
//! `run_queue` default) and run-to-completion waves (the reference).
//! Skipped when artifacts are absent.

use cmoe::eval::forward::DenseForward;
use cmoe::model::{model_config, ModelWeights};
use cmoe::runtime::XlaRuntime;
use cmoe::serving::{Engine, EngineConfig, ExecMode, GenParams, Request};
use cmoe::util::Rng;
use std::sync::Arc;

fn runtime() -> Option<Arc<XlaRuntime>> {
    let dir = cmoe::test_artifact_dir()?;
    Some(Arc::new(XlaRuntime::load(dir).expect("load runtime")))
}

fn tiny_models(rng: &mut Rng) -> (ModelWeights, ModelWeights) {
    let cfg = model_config("tiny").unwrap();
    let dense = ModelWeights::random(&cfg, rng);
    let fwd = DenseForward::new(&dense);
    let calib: Vec<usize> = (0..96).map(|_| rng.below(cfg.vocab)).collect();
    let profiles: Vec<_> = fwd
        .capture_hidden(&calib)
        .iter()
        .map(|h| cmoe::profiling::ActivationProfile::from_hidden(h, 24))
        .collect();
    let moe = cmoe::converter::convert_model(
        &dense,
        &profiles,
        &"S2A2E8".parse().unwrap(),
        &cmoe::converter::ConvertOptions::default(),
    )
    .unwrap()
    .model;
    (dense, moe)
}

fn requests(n: usize, rng: &mut Rng, max_new: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let prompt: Vec<usize> = (0..12).map(|_| rng.below(250)).collect();
            Request::new(
                i as u64,
                prompt,
                GenParams { max_new_tokens: max_new, temperature: 0.0, seed: i as u64, stop_token: None },
            )
        })
        .collect()
}

#[test]
fn dense_engine_generates() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(411);
    let (dense, _) = tiny_models(&mut rng);
    let mut cfg = EngineConfig::dense("tiny", 128);
    cfg.batcher.buckets = vec![1];
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    let engine = Engine::new(rt, dense, cfg).unwrap();
    let results = engine.run_queue(requests(2, &mut rng, 8)).unwrap();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert_eq!(r.tokens.len(), 8);
        assert!(r.tokens.iter().all(|&t| t < 256));
        let ttft = r.ttft.expect("served request must have a first token");
        assert!(ttft.as_nanos() > 0);
        assert_eq!(r.ttft_steps, Some(1), "short prompts prefill in one chunk");
    }
    let m = engine.metrics.lock().unwrap();
    // continuous scheduling: one run summary, per-step accounting in
    // the scheduler gauges
    assert_eq!(m.waves.len(), 1);
    assert!(m.decode_tps() > 0.0);
    assert_eq!(m.scheduler.admitted, 2);
    assert_eq!(m.scheduler.retired, 2);
    assert!(m.scheduler.decode_steps > 0);
}

#[test]
fn engine_greedy_matches_rust_forward_greedy() {
    // the serving stack (artifacts) and the rust reference must produce
    // the SAME greedy continuation
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(412);
    let (dense, _) = tiny_models(&mut rng);
    let prompt: Vec<usize> = (0..16).map(|_| rng.below(250)).collect();

    // rust reference greedy continuation
    let fwd = DenseForward::new(&dense);
    let mut ref_tokens = Vec::new();
    let mut ctx = prompt.clone();
    for _ in 0..6 {
        let logits = fwd.logits(&ctx);
        let last = logits.row(ctx.len() - 1);
        let tok = (0..dense.config.vocab)
            .max_by(|&a, &b| last[a].partial_cmp(&last[b]).unwrap())
            .unwrap();
        ref_tokens.push(tok);
        ctx.push(tok);
    }

    let mut cfg = EngineConfig::dense("tiny", 128);
    cfg.batcher.buckets = vec![1];
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    let engine = Engine::new(rt, dense, cfg).unwrap();
    let results = engine
        .run_queue(vec![Request::new(
            0,
            prompt,
            GenParams { max_new_tokens: 6, temperature: 0.0, seed: 0, stop_token: None },
        )])
        .unwrap();
    assert_eq!(results[0].tokens, ref_tokens, "greedy decode paths disagree");
}

#[test]
fn moe_monolithic_engine_generates() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(413);
    let (_, moe) = tiny_models(&mut rng);
    let mut cfg =
        EngineConfig::moe("tiny", 128, "S2A2E8".parse().unwrap(), ExecMode::MoeMonolithic);
    cfg.batcher.buckets = vec![1];
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    let engine = Engine::new(rt, moe, cfg).unwrap();
    let results = engine.run_queue(requests(1, &mut rng, 6)).unwrap();
    assert_eq!(results[0].tokens.len(), 6);
}

#[test]
fn moe_orchestrated_matches_monolithic_greedy() {
    // the FLOP-saving orchestrated path must agree with the masked
    // monolithic path (same routing math, different execution)
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(414);
    let (_, moe) = tiny_models(&mut rng);
    let prompt: Vec<usize> = (0..16).map(|_| rng.below(250)).collect();
    let gen = |mode: ExecMode, model: ModelWeights, rt: Arc<XlaRuntime>| {
        let mut cfg = EngineConfig::moe("tiny", 128, "S2A2E8".parse().unwrap(), mode);
        cfg.batcher.buckets = vec![1];
        cfg.batcher.max_wait = std::time::Duration::ZERO;
        cfg.balance = None; // bias adaptation off for exact comparison
        let engine = Engine::new(rt, model, cfg).unwrap();
        engine
            .run_queue(vec![Request::new(
                0,
                prompt.clone(),
                GenParams { max_new_tokens: 5, temperature: 0.0, seed: 0, stop_token: None },
            )])
            .unwrap()[0]
            .tokens
            .clone()
    };
    let mono = gen(ExecMode::MoeMonolithic, moe.clone(), rt.clone());
    let orch = gen(ExecMode::MoeOrchestrated, moe, rt);
    assert_eq!(mono, orch, "orchestrated and monolithic MoE disagree");
}

/// Build random-weight `small` dense + converted models (the `small`
/// artifact family is the only one compiled at batch > 1, which the
/// mixed-length batch tests need).
fn small_models(rng: &mut Rng) -> (ModelWeights, ModelWeights) {
    let cfg = model_config("small").unwrap();
    let dense = ModelWeights::random(&cfg, rng);
    let fwd = DenseForward::new(&dense);
    let calib: Vec<usize> = (0..192).map(|_| rng.below(cfg.vocab)).collect();
    let profiles: Vec<_> = fwd
        .capture_hidden(&calib)
        .iter()
        .map(|h| cmoe::profiling::ActivationProfile::from_hidden(h, 24))
        .collect();
    let moe = cmoe::converter::convert_model(
        &dense,
        &profiles,
        &"S3A3E8".parse().unwrap(),
        &cmoe::converter::ConvertOptions::default(),
    )
    .unwrap()
    .model;
    (dense, moe)
}

/// Mixed-length batch: heterogeneous prompts (all ≤ the compiled s so
/// each request's prefill padding is scheduling-independent), mixed
/// max_new_tokens, and stop tokens on half the requests.
fn mixed_requests(first_pass: Option<&[Vec<usize>]>, rng: &mut Rng) -> Vec<Request> {
    let lens = [12usize, 4, 9, 15, 6, 11];
    let max_new = [12usize, 3, 8, 5, 10, 2];
    (0..6)
        .map(|i| {
            let prompt: Vec<usize> = (0..lens[i]).map(|_| rng.below(250)).collect();
            // second pass: requests 0/2/4 stop at their first pass's
            // 2nd token — genuine mid-batch early retirement
            let stop_token = first_pass.and_then(|toks| {
                // only when unambiguous: the 2nd token must differ from
                // the 1st, so stopping can only happen at index 1
                if i % 2 == 0 && toks[i].len() > 1 && toks[i][1] != toks[i][0] {
                    Some(toks[i][1])
                } else {
                    None
                }
            });
            Request::new(
                i as u64,
                prompt,
                GenParams { max_new_tokens: max_new[i], temperature: 0.0, seed: i as u64, stop_token },
            )
        })
        .collect()
}

#[test]
fn continuous_matches_waves_mixed_lengths_and_stops_all_modes() {
    // Per-request tokens under continuous in-flight batching must be
    // identical to the run-to-completion wave engine, for every exec
    // mode, on one batch mixing prompt lengths, generation lengths and
    // stop tokens. Fresh engine per run: the orchestrated bias adapter
    // is engine state (balance is disabled anyway for exactness).
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(417);
    let (dense, moe) = small_models(&mut rng);
    let spec: cmoe::model::MoeSpec = "S3A3E8".parse().unwrap();
    let modes: [(ExecMode, &ModelWeights); 3] = [
        (ExecMode::Dense, &dense),
        (ExecMode::MoeMonolithic, &moe),
        (ExecMode::MoeOrchestrated, &moe),
    ];
    for (mode, model) in modes {
        let mk_cfg = || {
            let mut cfg = match mode {
                ExecMode::Dense => EngineConfig::dense("small", 64),
                m => EngineConfig::moe("small", 64, spec, m),
            };
            cfg.batcher.buckets = vec![1, 8];
            cfg.batcher.max_wait = std::time::Duration::ZERO;
            cfg.balance = None;
            cfg
        };
        let run = |continuous: bool, reqs: Vec<Request>| {
            let engine = Engine::new(rt.clone(), model.clone(), mk_cfg()).unwrap();
            let results = if continuous {
                engine.run_queue(reqs).unwrap()
            } else {
                engine.run_queue_waves(reqs).unwrap()
            };
            results.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
        };

        // pass 1 (no stops) discovers tokens; pass 2 adds stop tokens
        let mut prng = Rng::new(99);
        let probe = run(true, mixed_requests(None, &mut prng));
        let mut prng2 = Rng::new(99);
        let reqs = mixed_requests(Some(probe.as_slice()), &mut prng2);
        let max_new: Vec<usize> = reqs.iter().map(|r| r.params.max_new_tokens).collect();
        let stops: Vec<Option<usize>> = reqs.iter().map(|r| r.params.stop_token).collect();

        let cont = run(true, reqs.clone());
        let waves = run(false, reqs);
        assert_eq!(cont, waves, "continuous vs waves diverged in {mode:?}");
        for (i, toks) in cont.iter().enumerate() {
            assert!(!toks.is_empty() && toks.len() <= max_new[i]);
            if let Some(stop) = stops[i] {
                // stop at its 2nd token → early retirement mid-batch
                assert_eq!(toks.len(), 2, "request {i} ignored its stop token in {mode:?}");
                assert_eq!(*toks.last().unwrap(), stop);
            }
        }
        // lengths genuinely differ inside the one batch
        let lens: std::collections::HashSet<usize> = cont.iter().map(|t| t.len()).collect();
        assert!(lens.len() >= 2, "batch was not mixed-length: {lens:?}");
    }
}

#[test]
fn shared_system_prompt_prefix_cache_matches_waves_all_modes() {
    // Paged KV + prefix cache on the artifact path: a batch whose
    // requests share a system prompt must produce per-request tokens
    // identical to (a) the run-to-completion wave engine and (b) the
    // continuous path with sharing off — the cache is a memory dedup,
    // never a semantic change. All prompts share one length so their
    // padded prefill rows share leading pages.
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(418);
    let (dense, moe) = small_models(&mut rng);
    let spec: cmoe::model::MoeSpec = "S3A3E8".parse().unwrap();
    let sys: Vec<usize> = (0..8).map(|_| rng.below(250)).collect();
    let reqs: Vec<Request> = (0..6)
        .map(|i| {
            let mut prompt = sys.clone();
            prompt.extend((0..4).map(|_| rng.below(250)));
            Request::new(
                i as u64,
                prompt,
                GenParams {
                    max_new_tokens: 4 + i % 3,
                    temperature: 0.0,
                    seed: i as u64,
                    stop_token: None,
                },
            )
        })
        .collect();
    let modes: [(ExecMode, &ModelWeights); 3] = [
        (ExecMode::Dense, &dense),
        (ExecMode::MoeMonolithic, &moe),
        (ExecMode::MoeOrchestrated, &moe),
    ];
    for (mode, model) in modes {
        let run = |prefix: bool, continuous: bool| {
            let mut cfg = match mode {
                ExecMode::Dense => EngineConfig::dense("small", 64),
                m => EngineConfig::moe("small", 64, spec, m),
            };
            cfg.batcher.buckets = vec![1, 8];
            cfg.batcher.max_wait = std::time::Duration::ZERO;
            cfg.balance = None;
            cfg.page_len = 4;
            cfg.prefix_cache = prefix;
            let engine = Engine::new(rt.clone(), model.clone(), cfg).unwrap();
            let results = if continuous {
                engine.run_queue(reqs.clone()).unwrap()
            } else {
                engine.run_queue_waves(reqs.clone()).unwrap()
            };
            let shared_maps = engine.metrics.lock().unwrap().pages.shared_maps;
            (results.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>(), shared_maps)
        };
        let (waves, _) = run(false, false);
        let (cont, _) = run(false, true);
        let (cont_prefix, shared_maps) = run(true, true);
        assert_eq!(cont, waves, "continuous vs waves diverged in {mode:?}");
        assert_eq!(cont_prefix, waves, "prefix sharing changed tokens in {mode:?}");
        // the batch admits together, so rows after the first map the
        // first row's padded-prefix pages instead of storing copies
        assert!(
            shared_maps >= 5,
            "expected page dedup across the shared-prompt batch, saw {shared_maps} maps in {mode:?}"
        );
        let lens: std::collections::HashSet<usize> = cont_prefix.iter().map(|t| t.len()).collect();
        assert!(lens.len() >= 2, "batch was not mixed-length: {lens:?}");
    }
}

#[test]
fn stop_token_halts_generation() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(415);
    let (dense, _) = tiny_models(&mut rng);
    let mut cfg = EngineConfig::dense("tiny", 128);
    cfg.batcher.buckets = vec![1];
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    let engine = Engine::new(rt, dense, cfg).unwrap();
    // greedy output of the first step becomes the stop token: run once
    // to discover it, then rerun with it as stop
    let r1 = engine
        .run_queue(vec![Request::new(0, vec![1, 2, 3], GenParams::default())])
        .unwrap();
    let first = r1[0].tokens[0];
    let r2 = engine
        .run_queue(vec![Request::new(
            1,
            vec![1, 2, 3],
            GenParams { stop_token: Some(first), ..GenParams::default() },
        )])
        .unwrap();
    assert_eq!(r2[0].tokens, vec![first]);
}
