//! Integration: the serving engine end-to-end in all three exec modes.
//! Skipped when artifacts are absent.

use cmoe::eval::forward::DenseForward;
use cmoe::model::{model_config, ModelWeights};
use cmoe::runtime::XlaRuntime;
use cmoe::serving::{Engine, EngineConfig, ExecMode, GenParams, Request};
use cmoe::util::Rng;
use std::sync::Arc;

fn runtime() -> Option<Arc<XlaRuntime>> {
    let dir = cmoe::test_artifact_dir()?;
    Some(Arc::new(XlaRuntime::load(dir).expect("load runtime")))
}

fn tiny_models(rng: &mut Rng) -> (ModelWeights, ModelWeights) {
    let cfg = model_config("tiny").unwrap();
    let dense = ModelWeights::random(&cfg, rng);
    let fwd = DenseForward::new(&dense);
    let calib: Vec<usize> = (0..96).map(|_| rng.below(cfg.vocab)).collect();
    let profiles: Vec<_> = fwd
        .capture_hidden(&calib)
        .iter()
        .map(|h| cmoe::profiling::ActivationProfile::from_hidden(h, 24))
        .collect();
    let moe = cmoe::converter::convert_model(
        &dense,
        &profiles,
        &"S2A2E8".parse().unwrap(),
        &cmoe::converter::ConvertOptions::default(),
    )
    .unwrap()
    .model;
    (dense, moe)
}

fn requests(n: usize, rng: &mut Rng, max_new: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let prompt: Vec<usize> = (0..12).map(|_| rng.below(250)).collect();
            Request::new(
                i as u64,
                prompt,
                GenParams { max_new_tokens: max_new, temperature: 0.0, seed: i as u64, stop_token: None },
            )
        })
        .collect()
}

#[test]
fn dense_engine_generates() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(411);
    let (dense, _) = tiny_models(&mut rng);
    let mut cfg = EngineConfig::dense("tiny", 128);
    cfg.batcher.buckets = vec![1];
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    let engine = Engine::new(rt, dense, cfg).unwrap();
    let results = engine.run_queue(requests(2, &mut rng, 8)).unwrap();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert_eq!(r.tokens.len(), 8);
        assert!(r.tokens.iter().all(|&t| t < 256));
        assert!(r.ttft.as_nanos() > 0);
    }
    let m = engine.metrics.lock().unwrap();
    assert_eq!(m.waves.len(), 2);
    assert!(m.decode_tps() > 0.0);
}

#[test]
fn engine_greedy_matches_rust_forward_greedy() {
    // the serving stack (artifacts) and the rust reference must produce
    // the SAME greedy continuation
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(412);
    let (dense, _) = tiny_models(&mut rng);
    let prompt: Vec<usize> = (0..16).map(|_| rng.below(250)).collect();

    // rust reference greedy continuation
    let fwd = DenseForward::new(&dense);
    let mut ref_tokens = Vec::new();
    let mut ctx = prompt.clone();
    for _ in 0..6 {
        let logits = fwd.logits(&ctx);
        let last = logits.row(ctx.len() - 1);
        let tok = (0..dense.config.vocab)
            .max_by(|&a, &b| last[a].partial_cmp(&last[b]).unwrap())
            .unwrap();
        ref_tokens.push(tok);
        ctx.push(tok);
    }

    let mut cfg = EngineConfig::dense("tiny", 128);
    cfg.batcher.buckets = vec![1];
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    let engine = Engine::new(rt, dense, cfg).unwrap();
    let results = engine
        .run_queue(vec![Request::new(
            0,
            prompt,
            GenParams { max_new_tokens: 6, temperature: 0.0, seed: 0, stop_token: None },
        )])
        .unwrap();
    assert_eq!(results[0].tokens, ref_tokens, "greedy decode paths disagree");
}

#[test]
fn moe_monolithic_engine_generates() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(413);
    let (_, moe) = tiny_models(&mut rng);
    let mut cfg =
        EngineConfig::moe("tiny", 128, "S2A2E8".parse().unwrap(), ExecMode::MoeMonolithic);
    cfg.batcher.buckets = vec![1];
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    let engine = Engine::new(rt, moe, cfg).unwrap();
    let results = engine.run_queue(requests(1, &mut rng, 6)).unwrap();
    assert_eq!(results[0].tokens.len(), 6);
}

#[test]
fn moe_orchestrated_matches_monolithic_greedy() {
    // the FLOP-saving orchestrated path must agree with the masked
    // monolithic path (same routing math, different execution)
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(414);
    let (_, moe) = tiny_models(&mut rng);
    let prompt: Vec<usize> = (0..16).map(|_| rng.below(250)).collect();
    let gen = |mode: ExecMode, model: ModelWeights, rt: Arc<XlaRuntime>| {
        let mut cfg = EngineConfig::moe("tiny", 128, "S2A2E8".parse().unwrap(), mode);
        cfg.batcher.buckets = vec![1];
        cfg.batcher.max_wait = std::time::Duration::ZERO;
        cfg.balance = None; // bias adaptation off for exact comparison
        let engine = Engine::new(rt, model, cfg).unwrap();
        engine
            .run_queue(vec![Request::new(
                0,
                prompt.clone(),
                GenParams { max_new_tokens: 5, temperature: 0.0, seed: 0, stop_token: None },
            )])
            .unwrap()[0]
            .tokens
            .clone()
    };
    let mono = gen(ExecMode::MoeMonolithic, moe.clone(), rt.clone());
    let orch = gen(ExecMode::MoeOrchestrated, moe, rt);
    assert_eq!(mono, orch, "orchestrated and monolithic MoE disagree");
}

#[test]
fn stop_token_halts_generation() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(415);
    let (dense, _) = tiny_models(&mut rng);
    let mut cfg = EngineConfig::dense("tiny", 128);
    cfg.batcher.buckets = vec![1];
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    let engine = Engine::new(rt, dense, cfg).unwrap();
    // greedy output of the first step becomes the stop token: run once
    // to discover it, then rerun with it as stop
    let r1 = engine
        .run_queue(vec![Request::new(0, vec![1, 2, 3], GenParams::default())])
        .unwrap();
    let first = r1[0].tokens[0];
    let r2 = engine
        .run_queue(vec![Request::new(
            1,
            vec![1, 2, 3],
            GenParams { stop_token: Some(first), ..GenParams::default() },
        )])
        .unwrap();
    assert_eq!(r2[0].tokens, vec![first]);
}
