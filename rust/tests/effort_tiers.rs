//! Effort-tier e2e suite over the host stub backend (ROADMAP item 4):
//! per-request activation-ratio operating points must actually change
//! what the backend computes for degraded rows — and change *nothing*
//! for full-effort rows.
//!
//! * **Degraded rows run cheaper, meterably**: a mixed-tier trace
//!   leaves `SchedulerMetrics::activated_fraction(Degraded)` at the
//!   configured ratio (and `Full` at 1.0), with every decoded row
//!   attributed to its tier;
//! * **Full-tier streams are bit-identical with tiering on or off**:
//!   the untiered `stub_reference` stays the oracle for `Full`
//!   requests no matter what ratio degraded neighbors run at;
//! * **tiers survive preemption** (Park AND Drop): preempted degraded
//!   requests resume at their ratio and reproduce the run-to-
//!   completion `stub_reference_tiered` stream exactly;
//! * **bounded admission degrades end to end**: a request degraded by
//!   the overflow margin is served at the degraded ratio and echoes
//!   `tier: Degraded` in its result.

use cmoe::prop_assert;
use cmoe::serving::{
    stub_reference, stub_reference_tiered, BatcherConfig, Clock, ContinuousSession, EffortTier,
    GenParams, PreemptMode, Priority, Request, StubForward, SubmitOutcome, TierRatios,
};
use cmoe::util::{prop, Rng};
use std::collections::VecDeque;
use std::time::Duration;

const VOCAB: usize = 19;
const KV_CAP: usize = 64;

fn tiered_cfg(buckets: Vec<usize>, ratios: TierRatios) -> BatcherConfig {
    BatcherConfig {
        buckets,
        max_wait: Duration::ZERO,
        tier_ratios: ratios,
        ..Default::default()
    }
}

fn session(
    buckets: Vec<usize>,
    ratios: TierRatios,
    preempt: PreemptMode,
) -> ContinuousSession<StubForward> {
    let pool = *buckets.iter().max().unwrap();
    let mut cfg = tiered_cfg(buckets, ratios);
    cfg.preempt = preempt;
    ContinuousSession::with_clock(cfg, StubForward::new(pool, VOCAB, KV_CAP), Clock::manual())
        .unwrap()
}

fn random_request(id: u64, rng: &mut Rng) -> Request {
    let prompt: Vec<usize> = (0..1 + rng.below(8)).map(|_| rng.below(VOCAB)).collect();
    let params = GenParams {
        max_new_tokens: 1 + rng.below(12),
        temperature: if rng.f32() < 0.5 { 0.0 } else { 0.8 },
        seed: rng.next_u64(),
        stop_token: if rng.f32() < 0.2 { Some(rng.below(VOCAB)) } else { None },
    };
    let tier = if rng.f32() < 0.5 { EffortTier::Degraded } else { EffortTier::Full };
    Request::new(id, prompt, params).with_tier(tier)
}

/// Drive a session to completion over a shuffled-arrival trace.
fn run_trace(
    sess: &mut ContinuousSession<StubForward>,
    reqs: &[Request],
    rng: &mut Rng,
) -> Result<Vec<cmoe::serving::RequestResult>, String> {
    let mut pending: VecDeque<Request> = reqs.iter().cloned().collect();
    let mut results = Vec::new();
    let mut guard = 0;
    while !(pending.is_empty() && sess.is_idle()) {
        for _ in 0..rng.below(3) {
            if let Some(r) = pending.pop_front() {
                sess.enqueue(r);
            }
        }
        results.extend(sess.step().map_err(|e| e.to_string())?);
        guard += 1;
        if guard >= 100_000 {
            return Err("trace failed to converge".into());
        }
    }
    Ok(results)
}

#[test]
fn prop_tiered_streams_match_reference_and_meter_activation() {
    let ratios = TierRatios { full: 1.0, degraded: 0.25 };
    prop::check(
        "mixed-tier traces: per-tier token identity + activated-fraction metering",
        prop::Config { cases: 60, max_size: 20, seed: 0x71E2 },
        |rng, size| {
            let buckets = vec![1 + rng.below(4)];
            let n_req = 1 + rng.below(size.max(1));
            let mut sess = session(buckets, ratios, PreemptMode::Off);
            let reqs: Vec<Request> = (0..n_req).map(|i| random_request(i as u64, rng)).collect();
            let results = run_trace(&mut sess, &reqs, rng)?;
            prop_assert!(results.len() == n_req, "lost requests");

            let mut saw_degraded = false;
            for r in &results {
                let req = &reqs[r.id as usize];
                prop_assert!(r.tier == req.tier, "request {} tier not echoed", r.id);
                // the tier-aware run-to-completion oracle
                let want = stub_reference_tiered(req, VOCAB, KV_CAP, ratios);
                prop_assert!(
                    r.tokens == want,
                    "request {} ({:?}) diverged from tiered reference",
                    r.id,
                    req.tier
                );
                // Full-tier rows must be untouched by tiering: the
                // untiered oracle agrees exactly
                if req.tier == EffortTier::Full {
                    prop_assert!(
                        r.tokens == stub_reference(req, VOCAB, KV_CAP),
                        "full-tier request {} changed under tiering",
                        r.id
                    );
                } else {
                    saw_degraded = true;
                }
            }

            // metering: every decoded row lands in its tier's gauge at
            // the configured ratio. The first token of each request
            // comes from the prefill outcome, not a decode row, so the
            // gauge counts tokens-after-the-first.
            let m = sess.metrics();
            let rows: u64 = results.iter().map(|r| r.tokens.len() as u64 - 1).sum();
            prop_assert!(
                m.tier_row_steps.iter().sum::<u64>() == rows,
                "tier row-steps {} != decoded rows {rows}",
                m.tier_row_steps.iter().sum::<u64>()
            );
            if m.tier_row_steps[EffortTier::Degraded.index()] > 0 {
                let af = m.activated_fraction(EffortTier::Degraded);
                prop_assert!((af - 0.25).abs() < 1e-9, "degraded activation {af} != 0.25");
            }
            if m.tier_row_steps[EffortTier::Full.index()] > 0 {
                let af = m.activated_fraction(EffortTier::Full);
                prop_assert!((af - 1.0).abs() < 1e-9, "full activation {af} != 1.0");
            }
            prop_assert!(saw_degraded || n_req < 4, "large trace never degraded — vacuous");
            Ok(())
        },
    );
}

#[test]
fn full_tier_streams_identical_with_tiering_on_and_off() {
    // same trace, three sessions: tiering off (all ratios 1), tiering
    // on, and tiering on with degraded neighbors — the Full requests'
    // streams must be bitwise identical across all three
    let mut rng = Rng::new(0x71E3);
    let full_reqs: Vec<Request> =
        (0..8).map(|i| random_request(i, &mut rng).with_tier(EffortTier::Full)).collect();
    let degraded: Vec<Request> = (8..12)
        .map(|i| random_request(i, &mut rng).with_tier(EffortTier::Degraded))
        .collect();

    let run = |reqs: &[Request], ratios: TierRatios| -> Vec<(u64, Vec<usize>)> {
        let mut sess = session(vec![4], ratios, PreemptMode::Off);
        let mut drive_rng = Rng::new(0xD21E);
        let mut out: Vec<(u64, Vec<usize>)> = run_trace(&mut sess, reqs, &mut drive_rng)
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        out.sort();
        out
    };

    let off = run(&full_reqs, TierRatios { full: 1.0, degraded: 1.0 });
    let on = run(&full_reqs, TierRatios { full: 1.0, degraded: 0.25 });
    assert_eq!(off, on, "tiering on/off changed full-tier streams");

    // same full requests with degraded traffic interleaved: per-row
    // tiering means neighbors cannot perturb a Full row
    let mut mixed: Vec<Request> = full_reqs.clone();
    mixed.extend(degraded.clone());
    let mixed_out = run(&mixed, TierRatios { full: 1.0, degraded: 0.25 });
    for (id, toks) in &off {
        let got = &mixed_out.iter().find(|(i, _)| i == id).unwrap().1;
        assert_eq!(got, toks, "request {id} perturbed by degraded neighbors");
    }
    // and the degraded neighbors really are degraded
    for r in &degraded {
        let got = &mixed_out.iter().find(|(i, _)| *i == r.id).unwrap().1;
        let want = stub_reference_tiered(r, VOCAB, KV_CAP, TierRatios { full: 1.0, degraded: 0.25 });
        assert_eq!(got, &want, "degraded request {} off its tiered reference", r.id);
    }
}

#[test]
fn prop_tiers_survive_preemption_in_both_modes() {
    let ratios = TierRatios { full: 1.0, degraded: 0.25 };
    // prop::check takes Fn, so the cross-case counter lives in a Cell
    let total_preemptions = std::cell::Cell::new(0u64);
    prop::check(
        "preempt/resume (park and drop) preserves tier and token stream",
        prop::Config { cases: 60, max_size: 20, seed: 0x71E4 },
        |rng, size| {
            for &mode in &[PreemptMode::Park, PreemptMode::Drop] {
                let buckets = vec![1 + rng.below(3)];
                let n_req = 1 + rng.below(size.max(1));
                let mut sess = session(buckets, ratios, mode);
                let reqs: Vec<Request> = (0..n_req)
                    .map(|i| {
                        let mut r = random_request(i as u64, rng);
                        // tight High deadlines force preemption; keep
                        // tiers on victims and aggressors alike
                        if rng.f32() < 0.4 {
                            r = r.with_priority(Priority::High);
                            r = r.with_deadline_steps(rng.below(3) as u64);
                        } else if rng.f32() < 0.3 {
                            r = r.with_priority(Priority::Low);
                        }
                        r
                    })
                    .collect();
                let results = run_trace(&mut sess, &reqs, rng)?;
                let failures = sess.take_failures();
                prop_assert!(failures.is_empty(), "unexpected failures: {failures:?}");
                prop_assert!(results.len() == n_req, "lost requests under {mode:?}");
                for r in &results {
                    let req = &reqs[r.id as usize];
                    prop_assert!(
                        r.tier == req.tier,
                        "[{mode:?}] request {} lost its tier across preemption",
                        r.id
                    );
                    let want = stub_reference_tiered(req, VOCAB, KV_CAP, ratios);
                    prop_assert!(
                        r.tokens == want,
                        "[{mode:?}] request {} ({:?}) diverged after preemption",
                        r.id,
                        req.tier
                    );
                }
                total_preemptions.set(total_preemptions.get() + sess.metrics().preemptions);
            }
            Ok(())
        },
    );
    assert!(total_preemptions.get() > 0, "no trace ever preempted — property is vacuous");
}

#[test]
fn bounded_admission_degrades_and_serves_at_reduced_ratio() {
    // queue_cap 1 + margin 2 before any scheduler step: the first
    // arrival queues Full, the next two degrade into the overflow
    // margin, the fourth sheds
    let ratios = TierRatios { full: 1.0, degraded: 0.25 };
    let mut cfg = tiered_cfg(vec![1], ratios);
    cfg.queue_cap = Some(1);
    cfg.degrade_margin = 2;
    let mut sess =
        ContinuousSession::with_clock(cfg, StubForward::new(1, VOCAB, KV_CAP), Clock::manual())
            .unwrap();
    let mk = |id: u64| {
        Request::new(
            id,
            vec![1, 2, 3],
            GenParams { max_new_tokens: 6, temperature: 0.0, seed: id, stop_token: None },
        )
    };
    assert_eq!(sess.enqueue(mk(0)), SubmitOutcome::Queued);
    assert_eq!(sess.enqueue(mk(1)), SubmitOutcome::QueuedDegraded);
    assert_eq!(sess.enqueue(mk(2)), SubmitOutcome::QueuedDegraded);
    assert!(matches!(sess.enqueue(mk(3)), SubmitOutcome::Rejected(_)));

    let results = sess.drain().unwrap();
    assert_eq!(results.len(), 3);
    for r in &results {
        let want_tier = if r.id >= 1 { EffortTier::Degraded } else { EffortTier::Full };
        assert_eq!(r.tier, want_tier, "request {} tier", r.id);
        // the degrade applied by admission, not just the caller, maps
        // to the reduced operating point end to end
        let mut req = mk(r.id);
        req.tier = want_tier;
        assert_eq!(
            r.tokens,
            stub_reference_tiered(&req, VOCAB, KV_CAP, ratios),
            "request {} not served at its admitted tier",
            r.id
        );
    }
    let m = sess.metrics();
    assert!(m.tier_row_steps[EffortTier::Degraded.index()] > 0, "no degraded rows metered");
    assert!((m.activated_fraction(EffortTier::Degraded) - 0.25).abs() < 1e-9);
    assert!((m.activated_fraction(EffortTier::Full) - 1.0).abs() < 1e-9);
    // the engine-level summary surfaces the tier gauges
    let mut em = cmoe::serving::EngineMetrics::default();
    em.scheduler = m.clone();
    assert!(em.summary().contains("tiers:"), "summary missing tier segment: {}", em.summary());
}

#[test]
fn degraded_ratio_actually_changes_logits_not_just_metering() {
    // guard against a vacuous stub: at least some degraded requests
    // must produce different tokens than their full-effort run would
    let ratios = TierRatios { full: 1.0, degraded: 0.25 };
    let mut rng = Rng::new(0x71E5);
    let mut diverged = 0usize;
    for i in 0..40u64 {
        let mut r = random_request(i, &mut rng).with_tier(EffortTier::Degraded);
        // long prompts make the truncated-context window observable
        r.prompt = (0..10 + rng.below(10)).map(|_| rng.below(VOCAB)).collect();
        let full = stub_reference(&r, VOCAB, KV_CAP);
        let degraded = stub_reference_tiered(&r, VOCAB, KV_CAP, ratios);
        if full != degraded {
            diverged += 1;
        }
    }
    assert!(diverged > 0, "degraded ratio never changed a token stream — stub is vacuous");
}
