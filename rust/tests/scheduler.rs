//! Host-only unit + property tests for the continuous-batching
//! scheduler (`serving::scheduler`). No compiled artifacts needed —
//! the whole suite runs on a fresh clone, which is the point: the
//! scheduler is the serving engine's control flow, and control flow is
//! what these invariants pin down:
//!
//! * bucket selection is minimal-covering,
//! * admission is FIFO in enqueue order,
//! * a slot is never double-assigned (`live + free == pool`),
//! * retired slots are reused before never-used slots,
//! * the batcher's `max_wait` hold window is honored (idle engine
//!   only),
//! * per-request token streams match the run-to-completion reference
//!   regardless of trace shape (the property test).

use cmoe::prop_assert;
use cmoe::serving::{
    stub_reference, BatcherConfig, ContinuousSession, GenParams, Request, Scheduler, StubForward,
};
use cmoe::util::prop;
use cmoe::util::Rng;
use std::time::{Duration, Instant};

const VOCAB: usize = 17;

fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
    let prompt: Vec<usize> = (0..prompt_len.max(1)).map(|j| (id as usize * 31 + j * 7) % VOCAB).collect();
    Request::new(
        id,
        prompt,
        GenParams { max_new_tokens: max_new, temperature: 0.0, seed: id, stop_token: None },
    )
}

fn session(buckets: Vec<usize>, max_wait: Duration) -> ContinuousSession<StubForward> {
    let pool = *buckets.iter().max().unwrap();
    ContinuousSession::new(
        BatcherConfig { buckets, max_wait, ..Default::default() },
        StubForward::new(pool, VOCAB, usize::MAX),
    )
    .unwrap()
}

// ---------------------------------------------------------------------------
// bucket selection
// ---------------------------------------------------------------------------

#[test]
fn bucket_selection_is_minimal_covering() {
    let s = Scheduler::new(&[1, 8, 32]).unwrap();
    assert_eq!(s.pool_size(), 32);
    for n in 1..=32 {
        let b = s.min_bucket(n);
        assert!(b >= n, "bucket {b} must cover {n}");
        // minimal: no configured bucket in [n, b)
        assert!(
            !s.buckets().iter().any(|&c| c >= n && c < b),
            "bucket {b} for {n} live is not minimal"
        );
    }
    assert_eq!(s.min_bucket(1), 1);
    assert_eq!(s.min_bucket(2), 8);
    assert_eq!(s.min_bucket(9), 32);
}

// ---------------------------------------------------------------------------
// admission order + slot accounting
// ---------------------------------------------------------------------------

#[test]
fn admission_is_fifo() {
    let mut sess = session(vec![2], Duration::ZERO);
    for i in 0..6 {
        sess.enqueue(req(i, 3, 2));
    }
    // pool of 2: ids {0,1} admitted at step 0; each finishes after its
    // 2nd token (1 decode step), freeing both slots for {2,3}, etc.
    let results = sess.drain().unwrap();
    let mut by_id: Vec<(u64, u64)> = results.iter().map(|r| (r.id, r.queued_steps)).collect();
    by_id.sort_unstable();
    let waits: Vec<u64> = by_id.iter().map(|&(_, w)| w).collect();
    assert_eq!(waits, vec![0, 0, 1, 1, 2, 2], "FIFO pairs admitted wave by wave");
}

#[test]
fn slots_never_double_assigned_and_recycled_first() {
    let mut s = Scheduler::new(&[1, 4]).unwrap();
    let now = Instant::now();
    let mut live = Vec::new();
    for i in 0..4 {
        let sid = s.assign(req(i, 2, 4), now, 0, now).unwrap();
        assert!(!live.contains(&sid), "slot {sid} double-assigned");
        live.push(sid);
    }
    assert_eq!(s.free_count(), 0);
    assert_eq!(s.live(), 4);
    // retire 2 and 1; LIFO reuse gives 1 back first, then 2 — both
    // before any hypothetical fresh slot (there are none left)
    s.retire(2).unwrap();
    s.retire(1).unwrap();
    assert_eq!(s.live() + s.free_count(), s.pool_size());
    assert_eq!(s.assign(req(10, 2, 4), now, 0, now).unwrap(), 1);
    assert_eq!(s.assign(req(11, 2, 4), now, 0, now).unwrap(), 2);
    assert_eq!(s.metrics.slot_reuses, 2);
}

#[test]
fn retired_slots_reused_before_fresh_via_session() {
    // pool 4, but requests trickle one at a time: the same slot should
    // be recycled instead of touching fresh slots
    let mut sess = session(vec![1, 4], Duration::ZERO);
    for i in 0..4 {
        sess.enqueue(req(i, 3, 1)); // 1 token: retire at prefill
        while !sess.is_idle() {
            sess.step().unwrap();
        }
    }
    let m = sess.metrics();
    assert_eq!(m.admitted, 4);
    assert_eq!(m.slot_reuses, 3, "slot 0 recycled for every follow-up request");
}

// ---------------------------------------------------------------------------
// max_wait hold window
// ---------------------------------------------------------------------------

#[test]
fn max_wait_holds_idle_engine_only() {
    let mut sess = session(vec![1, 8], Duration::from_secs(60));
    sess.enqueue(req(0, 3, 10)); // one long request…
    // idle + fresh + below max bucket: held, nothing admitted
    sess.step().unwrap();
    assert_eq!(sess.live(), 0);
    assert_eq!(sess.pending(), 1);
    // …filling to the max bucket releases immediately
    for i in 1..8 {
        sess.enqueue(req(i, 3, 1)); // 7 one-token requests retire at prefill
    }
    sess.step().unwrap();
    assert_eq!(sess.metrics().admitted, 8, "full queue released despite the window");
    assert_eq!(sess.live(), 1, "short requests retired at prefill");
    // a busy engine admits late arrivals immediately, no hold
    sess.enqueue(req(100, 3, 4));
    sess.step().unwrap();
    assert_eq!(sess.pending(), 0, "mid-flight admission skips the hold window");
    assert_eq!(sess.live(), 2);
}

#[test]
fn zero_wait_admits_single_request_immediately() {
    let mut sess = session(vec![1, 8], Duration::ZERO);
    sess.enqueue(req(0, 3, 2));
    sess.step().unwrap();
    assert_eq!(sess.live(), 1);
}

// ---------------------------------------------------------------------------
// property: any trace through the scheduler is token-exact, slots
// balance, and occupancy accounting is consistent
// ---------------------------------------------------------------------------

#[test]
fn prop_random_traces_are_token_exact_and_balanced() {
    prop::check(
        "continuous scheduling preserves per-request token streams",
        prop::Config { cases: 60, seed: 0x5C4ED, max_size: 40 },
        |rng: &mut Rng, size| {
            // random bucket ladder, arrivals, request shapes
            let mut buckets = vec![1 + rng.below(4)];
            while rng.f32() < 0.5 && buckets.len() < 4 {
                buckets.push(buckets.last().unwrap() + 1 + rng.below(8));
            }
            let kv_cap = 24 + rng.below(32);
            let n_req = 1 + rng.below(size.max(1));
            let pool = *buckets.iter().max().unwrap();
            let mut sess = ContinuousSession::new(
                BatcherConfig { buckets: buckets.clone(), max_wait: Duration::ZERO, ..Default::default() },
                StubForward::new(pool, VOCAB, kv_cap),
            )
            .unwrap();
            let mut reqs = Vec::new();
            for i in 0..n_req {
                let r = Request::new(
                    i as u64,
                    (0..1 + rng.below(12)).map(|_| rng.below(VOCAB)).collect(),
                    GenParams {
                        max_new_tokens: 1 + rng.below(20),
                        temperature: if rng.f32() < 0.5 { 0.0 } else { 0.8 },
                        seed: rng.next_u64(),
                        stop_token: if rng.f32() < 0.3 { Some(rng.below(VOCAB)) } else { None },
                    },
                );
                reqs.push(r);
            }
            // staggered arrivals: enqueue a random chunk, then step
            let mut pending: std::collections::VecDeque<Request> = reqs.iter().cloned().collect();
            let mut results = Vec::new();
            let mut guard = 0;
            while !(pending.is_empty() && sess.is_idle()) {
                let burst = rng.below(4);
                for _ in 0..burst {
                    if let Some(r) = pending.pop_front() {
                        sess.enqueue(r);
                    }
                }
                results.extend(sess.step().map_err(|e| e.to_string())?);
                guard += 1;
                prop_assert!(guard < 100_000, "scheduler failed to converge");
            }
            prop_assert!(results.len() == n_req, "lost requests: {} != {n_req}", results.len());
            for r in &results {
                let want = stub_reference(&reqs[r.id as usize], VOCAB, kv_cap);
                prop_assert!(
                    r.tokens == want,
                    "request {} diverged: {:?} != {:?}",
                    r.id,
                    r.tokens,
                    want
                );
            }
            let m = sess.metrics();
            prop_assert!(m.admitted == n_req as u64, "admitted {} != {n_req}", m.admitted);
            prop_assert!(m.retired == n_req as u64, "retired {} != {n_req}", m.retired);
            prop_assert!(
                m.live_row_steps <= m.bucket_row_steps,
                "occupancy over 100%: {} > {}",
                m.live_row_steps,
                m.bucket_row_steps
            );
            prop_assert!(
                sess.forward().live_contexts() == 0,
                "leaked {} slot contexts",
                sess.forward().live_contexts()
            );
            Ok(())
        },
    );
}

#[test]
fn prop_bucket_is_minimal_every_step() {
    // drive the scheduler manually and check the covering invariant on
    // each recorded step via the session's occupancy counters
    prop::check(
        "per-step bucket rows cover live rows minimally",
        prop::Config { cases: 40, seed: 0xB0CE7, max_size: 24 },
        |rng: &mut Rng, size| {
            let buckets = vec![1, 3, 9];
            let mut sess = ContinuousSession::new(
                BatcherConfig { buckets, max_wait: Duration::ZERO, ..Default::default() },
                StubForward::new(9, VOCAB, usize::MAX),
            )
            .unwrap();
            for i in 0..(1 + rng.below(size.max(1))) {
                sess.enqueue(req(i as u64, 1 + rng.below(6), 1 + rng.below(9)));
            }
            let mut prev_steps = 0;
            let mut prev_live = 0;
            let mut prev_bucket = 0;
            while !sess.is_idle() {
                sess.step().map_err(|e| e.to_string())?;
                let m = sess.metrics();
                if m.decode_steps > prev_steps {
                    let live = (m.live_row_steps - prev_live) as usize;
                    let bucket = (m.bucket_row_steps - prev_bucket) as usize;
                    prop_assert!(bucket >= live, "bucket {bucket} < live {live}");
                    prop_assert!(
                        [1usize, 3, 9].contains(&bucket),
                        "bucket {bucket} not configured"
                    );
                    prop_assert!(
                        ![1usize, 3, 9].iter().any(|&c| c >= live && c < bucket),
                        "bucket {bucket} for {live} live rows is not minimal"
                    );
                    prev_steps = m.decode_steps;
                    prev_live = m.live_row_steps;
                    prev_bucket = m.bucket_row_steps;
                }
            }
            Ok(())
        },
    );
}
