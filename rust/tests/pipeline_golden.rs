//! Golden test: the staged `Pipeline` with method `cmoe` must produce
//! output **bit-identical** to the classic `converter::convert_model`
//! path, and stage-artifact resume must reproduce the exact same model
//! from any checkpoint. Run explicitly by `scripts/check.sh`.

use cmoe::converter::{convert_model, ConvertOptions};
use cmoe::data::calibration::CalibrationSpec;
use cmoe::eval::forward::DenseForward;
use cmoe::model::{model_config, LayerFfn, ModelWeights, Router};
use cmoe::pipeline::{Pipeline, Stage};
use cmoe::profiling::ActivationProfile;
use cmoe::util::Rng;

fn tiny_setup(seed: u64) -> (ModelWeights, Vec<ActivationProfile>) {
    let cfg = model_config("tiny").unwrap();
    let mut rng = Rng::new(seed);
    let dense = ModelWeights::random(&cfg, &mut rng);
    let calib: Vec<usize> = (0..128).map(|_| rng.below(cfg.vocab)).collect();
    let profiles: Vec<ActivationProfile> = DenseForward::new(&dense)
        .capture_hidden(&calib)
        .iter()
        .map(|h| ActivationProfile::from_hidden(h, 24))
        .collect();
    (dense, profiles)
}

/// Field-by-field bitwise equality of two converted models.
fn assert_models_identical(a: &ModelWeights, b: &ModelWeights, what: &str) {
    assert_eq!(a.layers.len(), b.layers.len(), "{what}: layer count");
    for (l, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        let (LayerFfn::Moe(ma), LayerFfn::Moe(mb)) = (&la.ffn, &lb.ffn) else {
            panic!("{what}: layer {l} is not MoE on both sides");
        };
        assert_eq!(ma.spec, mb.spec, "{what}: layer {l} spec");
        assert_eq!(ma.shared_neurons, mb.shared_neurons, "{what}: layer {l} shared neurons");
        assert_eq!(ma.expert_neurons, mb.expert_neurons, "{what}: layer {l} expert neurons");
        assert_eq!(ma.representatives, mb.representatives, "{what}: layer {l} representatives");
        assert_eq!(ma.gate_scale, mb.gate_scale, "{what}: layer {l} gate scale");
        assert_eq!(ma.gate_bias, mb.gate_bias, "{what}: layer {l} gate bias");
        assert_eq!(ma.compensation, mb.compensation, "{what}: layer {l} compensation");
        assert_eq!(ma.shared.w_gate, mb.shared.w_gate, "{what}: layer {l} shared w_gate");
        assert_eq!(ma.shared.w_up, mb.shared.w_up, "{what}: layer {l} shared w_up");
        assert_eq!(ma.shared.w_down, mb.shared.w_down, "{what}: layer {l} shared w_down");
        assert_eq!(ma.experts.len(), mb.experts.len());
        for (e, (ea, eb)) in ma.experts.iter().zip(&mb.experts).enumerate() {
            assert_eq!(ea.w_gate, eb.w_gate, "{what}: layer {l} expert {e} w_gate");
            assert_eq!(ea.w_up, eb.w_up, "{what}: layer {l} expert {e} w_up");
            assert_eq!(ea.w_down, eb.w_down, "{what}: layer {l} expert {e} w_down");
        }
        match (&ma.router, &mb.router) {
            (Router::Analytical(ra), Router::Analytical(rb)) => {
                assert_eq!(ra.w_gate_r, rb.w_gate_r, "{what}: layer {l} router w_gate_r");
                assert_eq!(ra.w_up_r, rb.w_up_r, "{what}: layer {l} router w_up_r");
            }
            (Router::Linear(wa), Router::Linear(wb)) => {
                assert_eq!(wa, wb, "{what}: layer {l} linear router");
            }
            _ => panic!("{what}: layer {l} router kind differs"),
        }
    }
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("cmoe_pipeline_golden").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn pipeline_cmoe_is_bit_identical_to_convert_model() {
    let (dense, profiles) = tiny_setup(701);
    let spec = "S2A2E8".parse().unwrap();

    let reference =
        convert_model(&dense, &profiles, &spec, &ConvertOptions::default()).unwrap().model;
    let run = Pipeline::for_method("cmoe")
        .unwrap()
        .spec(spec)
        .with_profiles(profiles)
        .run(&dense)
        .unwrap();

    assert_models_identical(&reference, &run.model, "pipeline vs convert_model");

    // …down to the serialized bytes (deterministic .cmw layout)
    let dir = tmp_dir("bytes");
    let pa = dir.join("reference.cmw");
    let pb = dir.join("pipeline.cmw");
    reference.save(&pa).unwrap();
    run.model.save(&pb).unwrap();
    assert_eq!(
        std::fs::read(&pa).unwrap(),
        std::fs::read(&pb).unwrap(),
        "saved .cmw artifacts must be byte-identical"
    );

    // and the forward pass is literally the same function of the input
    let tokens: Vec<usize> = (0..12).map(|i| (i * 17) % 256).collect();
    let la = DenseForward::new(&reference).logits(&tokens);
    let lb = DenseForward::new(&run.model).logits(&tokens);
    assert_eq!(la.data, lb.data, "logits diverged");
}

#[test]
fn stage_artifacts_resume_bit_identically() {
    let cfg = model_config("tiny").unwrap();
    let mut rng = Rng::new(702);
    let dense = ModelWeights::random(&cfg, &mut rng);
    let calib = CalibrationSpec { examples: 1, seq: 64, k_a: 8, ..Default::default() };
    let spec: cmoe::model::MoeSpec = "S2A2E8".parse().unwrap();
    let dir = tmp_dir("resume");

    let mk = || {
        Pipeline::for_method("cmoe")
            .unwrap()
            .spec(spec)
            .calib(calib.clone())
    };
    let full = mk().save_stages(&dir).run(&dense).unwrap();
    // all three artifacts exist
    for f in ["profile.json", "partition.json", "router.cmw"] {
        assert!(dir.join(f).exists(), "{f} missing after --save-stages run");
    }

    for f in ["profile.json", "partition.json", "router.cmw"] {
        let resumed = mk().resume_from(dir.join(f)).run(&dense).unwrap();
        assert_models_identical(&full.model, &resumed.model, &format!("resume from {f}"));
        assert!(
            resumed.stages.iter().any(|s| s.resumed),
            "resume from {f} recorded no resumed stage"
        );
    }

    // resuming from the router artifact skips profiling AND partitioning
    let from_router = mk().resume_from(dir.join("router.cmw")).run(&dense).unwrap();
    assert!(from_router.stage(Stage::Profile).is_none(), "router resume must not re-profile");
    let part = from_router.stage(Stage::Partition).unwrap();
    assert!(part.resumed, "router resume must not re-partition");
}

#[test]
fn hybrid_resume_reuses_base_partition() {
    // The sweep pattern: partition once with the base method, then build
    // the +cmoe-router hybrid from the saved partition — identical to
    // running the hybrid end-to-end.
    let cfg = model_config("tiny").unwrap();
    let mut rng = Rng::new(703);
    let dense = ModelWeights::random(&cfg, &mut rng);
    let calib = CalibrationSpec { examples: 1, seq: 64, k_a: 8, ..Default::default() };
    let dir = tmp_dir("hybrid");

    let _base = Pipeline::for_method("moefication")
        .unwrap()
        .calib(calib.clone())
        .save_stages(&dir)
        .run(&dense)
        .unwrap();

    let direct = Pipeline::for_method("moefication+cmoe-router")
        .unwrap()
        .calib(calib.clone())
        .run(&dense)
        .unwrap();
    let resumed = Pipeline::for_method("moefication+cmoe-router")
        .unwrap()
        .calib(calib)
        .resume_from(dir.join("partition.json"))
        .run(&dense)
        .unwrap();
    assert_models_identical(&direct.model, &resumed.model, "hybrid via partition resume");
}

#[test]
fn finetuned_pipeline_matches_classic_convert_plus_finetune() {
    // The CLI's full path (convert + finetune) equals the classic
    // two-step recipe on the same calibration stream.
    let (dense, profiles) = tiny_setup(704);
    let spec = "S2A2E8".parse().unwrap();
    let calib = CalibrationSpec::default();
    let samples = 96usize;

    let mut classic =
        convert_model(&dense, &profiles, &spec, &ConvertOptions::default()).unwrap().model;
    let tokens = calib.tokens_of(samples.max(calib.examples * calib.seq));
    cmoe::pipeline::finetune_model(&mut classic, &dense, &tokens, samples, calib.seq).unwrap();

    let run = Pipeline::for_method("cmoe")
        .unwrap()
        .spec(spec)
        .calib(calib)
        .with_profiles(profiles)
        .finetune(samples)
        .run(&dense)
        .unwrap();
    assert_models_identical(&classic, &run.model, "finetuned pipeline");
}
