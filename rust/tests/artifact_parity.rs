//! Integration: the compiled XLA artifacts must agree with the pure-rust
//! reference forward pass on identical weights — this pins L1+L2 (jax /
//! Pallas) to L3 (rust) numerics. Skipped when `make artifacts` hasn't
//! run.

use cmoe::eval::forward::DenseForward;
use cmoe::model::{model_config, ModelWeights};
use cmoe::runtime::{ModelBuffers, XlaRuntime};
use cmoe::util::Rng;

fn runtime() -> Option<XlaRuntime> {
    let dir = cmoe::test_artifact_dir()?;
    Some(XlaRuntime::load(dir).expect("artifacts exist but failed to load"))
}

#[test]
fn prefill_artifact_matches_rust_forward() {
    let Some(rt) = runtime() else { return };
    let cfg = model_config("tiny").unwrap();
    let mut rng = Rng::new(401);
    let model = ModelWeights::random(&cfg, &mut rng);

    let tokens: Vec<usize> = (0..16).map(|_| rng.below(cfg.vocab)).collect();
    // rust reference
    let want = DenseForward::new(&model).logits(&tokens);

    // artifact
    let bufs = ModelBuffers::from_model(&rt, &model).unwrap();
    let toks_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
    let tok_buf = rt.upload_i32(&toks_i32, &[1, 16]).unwrap();
    let args = bufs.args_with(&[&tok_buf]);
    let out = rt.execute("prefill_dense_tiny_b1_s16_t128", &args).unwrap();
    let got = rt.download(&out[0], &[1, 16, cfg.vocab]).unwrap();

    let mut max_diff = 0.0f32;
    for t in 0..16 {
        for v in 0..cfg.vocab {
            let d = (got.data[t * cfg.vocab + v] - want.at2(t, v)).abs();
            max_diff = max_diff.max(d);
        }
    }
    assert!(max_diff < 2e-3, "artifact vs rust logits diverge: {max_diff}");
}

#[test]
fn decode_artifact_continues_prefill() {
    let Some(rt) = runtime() else { return };
    let cfg = model_config("tiny").unwrap();
    let mut rng = Rng::new(402);
    let model = ModelWeights::random(&cfg, &mut rng);
    let bufs = ModelBuffers::from_model(&rt, &model).unwrap();

    // 17 tokens: prefill 16, decode 1 — must match rust forward of all 17
    let tokens: Vec<usize> = (0..17).map(|_| rng.below(cfg.vocab)).collect();
    let want = DenseForward::new(&model).logits(&tokens);

    let toks_i32: Vec<i32> = tokens[..16].iter().map(|&t| t as i32).collect();
    let tok_buf = rt.upload_i32(&toks_i32, &[1, 16]).unwrap();
    let args = bufs.args_with(&[&tok_buf]);
    let out = rt.execute("prefill_dense_tiny_b1_s16_t128", &args).unwrap();
    let kv = &out[1];

    let step_tok = rt.upload_i32(&[tokens[16] as i32], &[1]).unwrap();
    let pos = rt.upload_i32(&[16], &[1]).unwrap(); // per-row pos ABI
    let args = bufs.args_with(&[&step_tok, kv, &pos]);
    let out = rt.execute("decode_dense_tiny_b1_t128", &args).unwrap();
    let got = rt.download(&out[0], &[1, cfg.vocab]).unwrap();

    let mut max_diff = 0.0f32;
    for v in 0..cfg.vocab {
        max_diff = max_diff.max((got.data[v] - want.at2(16, v)).abs());
    }
    assert!(max_diff < 2e-3, "decode logits diverge from rust forward: {max_diff}");
}

#[test]
fn moe_decode_artifact_matches_rust_moe_forward() {
    let Some(rt) = runtime() else { return };
    let cfg = model_config("tiny").unwrap();
    let mut rng = Rng::new(403);
    let model = ModelWeights::random(&cfg, &mut rng);

    // convert with the spec compiled for tiny (S2A2E8)
    let fwd = DenseForward::new(&model);
    let calib: Vec<usize> = (0..96).map(|_| rng.below(cfg.vocab)).collect();
    let profiles: Vec<_> = fwd
        .capture_hidden(&calib)
        .iter()
        .map(|h| cmoe::profiling::ActivationProfile::from_hidden(h, 24))
        .collect();
    let conv = cmoe::converter::convert_model(
        &model,
        &profiles,
        &"S2A2E8".parse().unwrap(),
        &cmoe::converter::ConvertOptions::default(),
    )
    .unwrap();

    // rust reference on the converted model
    let tokens: Vec<usize> = (0..17).map(|_| rng.below(cfg.vocab)).collect();
    let want = DenseForward::new(&conv.model).logits(&tokens);

    // artifact path
    let dense_bufs = ModelBuffers::from_model(&rt, &conv.model).unwrap();
    let moe_bufs = cmoe::runtime::MoeModelBuffers::from_model(&rt, &conv.model).unwrap();
    let toks_i32: Vec<i32> = tokens[..16].iter().map(|&t| t as i32).collect();
    let tok_buf = rt.upload_i32(&toks_i32, &[1, 16]).unwrap();
    let mut args: Vec<&xla::PjRtBuffer> = dense_bufs.named.values().collect();
    args.extend(moe_bufs.named.values());
    args.push(&tok_buf);
    let out = rt.execute("prefill_moe_tiny_S2A2E8_b1_s16_t128", &args).unwrap();
    let kv = &out[1];

    let step_tok = rt.upload_i32(&[tokens[16] as i32], &[1]).unwrap();
    let pos = rt.upload_i32(&[16], &[1]).unwrap(); // per-row pos ABI
    let mut args: Vec<&xla::PjRtBuffer> = dense_bufs.named.values().collect();
    args.extend(moe_bufs.named.values());
    args.push(&step_tok);
    args.push(kv);
    args.push(&pos);
    let out = rt.execute("decode_moe_tiny_S2A2E8_b1_t128", &args).unwrap();
    let got = rt.download(&out[0], &[1, cfg.vocab]).unwrap();

    let mut max_diff = 0.0f32;
    for v in 0..cfg.vocab {
        max_diff = max_diff.max((got.data[v] - want.at2(16, v)).abs());
    }
    assert!(max_diff < 5e-3, "MoE decode diverges from rust MoE forward: {max_diff}");
}
