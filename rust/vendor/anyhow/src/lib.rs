//! Offline stand-in for the `anyhow` crate (vendored subset).
//!
//! The build environment has no network access, so the workspace carries
//! this minimal re-implementation of exactly the surface the codebase
//! uses: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result` and `Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics mirror the real crate where it matters:
//!
//! * `{}` prints the outermost message, `{:#}` prints the whole context
//!   chain outermost-first joined by `": "`, `{:?}` prints the message
//!   plus a `Caused by:` list.
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`] (the message is captured; the source is not retained).

use std::fmt;

/// A string-chain error value. `chain[0]` is the root cause; each
/// `.context(..)` call pushes a new outermost message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message (mirrors
    /// `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.push(c.to_string());
        self
    }

    /// The outermost (most recently attached) message.
    fn outer(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, m) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.outer())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outer())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error` (same as
// the real anyhow), which is what makes this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option` (subset of
/// `anyhow::Context`).
pub trait Context<T> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(c)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/afba8d")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.root_cause().is_empty());
    }

    #[test]
    fn context_chain_formats() {
        let e = io_fail().context("reading config").unwrap_err();
        let plain = format!("{e}");
        let alt = format!("{e:#}");
        assert_eq!(plain, "reading config");
        assert!(alt.starts_with("reading config: "));
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fell through with {}", x))
        }
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", f(1).unwrap_err()), "fell through with 1");
    }
}
