//! Host-side stub of the `xla` PJRT bindings crate.
//!
//! The real crate wraps the PJRT C API around `libxla_extension`; that
//! shared library is not present in the offline build environment, so
//! this stand-in keeps the *type and method surface* the codebase uses
//! while being honest about what it can do:
//!
//! * **Transfers are real.** [`PjRtClient::buffer_from_host_buffer`],
//!   [`PjRtBuffer::to_literal_sync`] and [`Literal::to_vec`] round-trip
//!   f32/i32 data faithfully, so upload/download plumbing and argument
//!   ordering stay unit-testable.
//! * **Execution is not.** [`PjRtClient::compile`] returns
//!   [`Error::BackendUnavailable`]; any path that would actually run an
//!   HLO artifact fails loudly instead of fabricating numbers.
//!   Artifact-dependent tests and benches in the main crate detect the
//!   missing manifest or the failing compile and self-skip.
//!
//! Swapping the real bindings back in is a one-line `Cargo.toml` change;
//! no call site needs to be touched.

use std::fmt;

/// Stub error type (the real crate's `Error` is also an enum; call
/// sites only format it with `{:?}`).
#[derive(Debug, Clone)]
pub enum Error {
    /// Compilation/execution was requested but no XLA backend is linked
    /// into this build.
    BackendUnavailable(String),
    /// Host data does not match the declared shape.
    Shape(String),
    /// Reading an artifact file failed.
    Io(String),
    /// A literal was read back as the wrong element type.
    TypeMismatch { expected: &'static str, got: &'static str },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BackendUnavailable(m) => write!(f, "XLA backend unavailable: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::TypeMismatch { expected, got } => {
                write!(f, "literal type mismatch: expected {expected}, got {got}")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Typed host storage behind buffers and literals.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum HostData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostData {
    fn type_name(&self) -> &'static str {
        match self {
            HostData::F32(_) => "f32",
            HostData::I32(_) => "i32",
        }
    }

    fn len(&self) -> usize {
        match self {
            HostData::F32(v) => v.len(),
            HostData::I32(v) => v.len(),
        }
    }
}

/// Element types transferable to/from the (stub) device.
pub trait NativeType: Copy {
    #[doc(hidden)]
    const NAME: &'static str;
    #[doc(hidden)]
    fn to_host(data: &[Self]) -> HostData;
    #[doc(hidden)]
    fn from_host(h: &HostData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const NAME: &'static str = "f32";

    fn to_host(data: &[Self]) -> HostData {
        HostData::F32(data.to_vec())
    }

    fn from_host(h: &HostData) -> Option<Vec<Self>> {
        match h {
            HostData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const NAME: &'static str = "i32";

    fn to_host(data: &[Self]) -> HostData {
        HostData::I32(data.to_vec())
    }

    fn from_host(h: &HostData) -> Option<Vec<Self>> {
        match h {
            HostData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Stub PJRT client. `cpu()` always succeeds; only `compile` is
/// backend-dependent.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    /// Copy host data into a (host-resident) "device" buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let expect: usize = dims.iter().product();
        if expect != data.len() {
            return Err(Error::Shape(format!(
                "{} elements for dims {dims:?} (want {expect})",
                data.len()
            )));
        }
        Ok(PjRtBuffer { data: T::to_host(data), dims: dims.to_vec() })
    }

    /// Always fails in the stub: there is no XLA backend to compile with.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::BackendUnavailable(format!(
            "cannot compile '{}' (stub xla crate; link the real bindings to execute artifacts)",
            comp.name()
        )))
    }
}

/// Host-resident stand-in for a device buffer.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    data: HostData,
    dims: Vec<usize>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal { data: self.data.clone(), dims: self.dims.clone() })
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }
}

/// Host literal value.
#[derive(Debug, Clone)]
pub struct Literal {
    data: HostData,
    dims: Vec<usize>,
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_host(&self.data).ok_or(Error::TypeMismatch {
            expected: T::NAME,
            got: self.data.type_name(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }
}

/// Stub executable: unreachable through the public API (compile fails
/// first), but the methods exist so call sites type-check.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("execute_b on stub executable".into()))
    }

    pub fn execute(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("execute on stub executable".into()))
    }
}

/// Parsed (well — *read*) HLO text module. The stub keeps the raw text
/// and module name so diagnostics stay useful.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    name: String,
    text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file. IO errors are reported; the text is
    /// not validated (the real parser lives in the XLA library).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text =
            std::fs::read_to_string(path).map_err(|e| Error::Io(format!("{path}: {e}")))?;
        let name = text
            .lines()
            .find_map(|l| l.trim().strip_prefix("HloModule "))
            .map(|rest| {
                rest.split(|c: char| c == ',' || c.is_whitespace())
                    .next()
                    .unwrap_or("")
                    .to_string()
            })
            .unwrap_or_else(|| {
                std::path::Path::new(path)
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| "unknown".into())
            });
        Ok(HloModuleProto { name, text })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Raw HLO text (useful for debugging artifact mismatches).
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// Opaque computation handle built from a module proto.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { name: proto.name().to_string() }
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer(&[1.0f32, 2.0, 3.0, 4.0], &[2, 2], None).unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(lit.dims(), &[2, 2]);
    }

    #[test]
    fn i32_scalar_and_type_mismatch() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer(&[7i32], &[], None).unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1.0f32; 5], &[2, 2], None).is_err());
    }

    #[test]
    fn compile_reports_backend_unavailable() {
        let dir = std::env::temp_dir().join("xla_stub_test.hlo");
        std::fs::write(&dir, "HloModule test_mod, entry_computation_layout={()->f32[]}\n")
            .unwrap();
        let proto = HloModuleProto::from_text_file(dir.to_str().unwrap()).unwrap();
        assert_eq!(proto.name(), "test_mod");
        assert!(proto.text().contains("HloModule"));
        let comp = XlaComputation::from_proto(&proto);
        let c = PjRtClient::cpu().unwrap();
        let err = c.compile(&comp).unwrap_err();
        assert!(matches!(err, Error::BackendUnavailable(_)));
    }
}
